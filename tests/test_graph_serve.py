"""GraphServeEngine: correctness, batching behavior, cache amortization."""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import gcn_normalize
from repro.core.plan_cache import PlanCache
from repro.core.spmm import make_accel_spmm
from repro.serve.graph_engine import GraphRequest, GraphServeEngine

from conftest import make_powerlaw_csr, make_wide_csr


def _setup(n_graphs=3, backend="blocked", **ekw):
    engine = GraphServeEngine(backend=backend, **ekw)
    graphs, feats = {}, {}
    rng = np.random.default_rng(0)
    for i in range(n_graphs):
        gid = f"g{i}"
        g = gcn_normalize(make_powerlaw_csr(n=90 + 25 * i, seed=i))
        engine.register_graph(gid, g)
        graphs[gid] = g
        feats[gid] = jnp.asarray(rng.normal(size=(g.n_rows, 16 + 8 * i)),
                                 dtype=jnp.float32)
    return engine, graphs, feats


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["blocked", "pallas", "auto"])
def test_serve_matches_direct_operator(backend):
    engine, graphs, feats = _setup(backend=backend)
    reqs = [GraphRequest(gid, feats[gid]) for gid in graphs]
    engine.serve(reqs)
    for r in reqs:
        direct = make_accel_spmm(graphs[r.graph_id])(feats[r.graph_id])
        np.testing.assert_allclose(np.asarray(r.out), np.asarray(direct),
                                   atol=1e-4, rtol=1e-4)
        assert r.latency_s is not None and r.latency_s > 0


def test_same_graph_served_twice_partitions_once():
    """Acceptance criterion, end to end through the engine."""
    engine, graphs, feats = _setup(n_graphs=1)
    builds_after_register = engine.cache.builds
    assert builds_after_register == 1
    engine.serve([GraphRequest("g0", feats["g0"])])
    engine.serve([GraphRequest("g0", feats["g0"] * 2)])
    assert engine.cache.builds == 1, "serving must never re-partition"
    assert engine.cache.hits >= 2


def test_same_graph_requests_fuse_along_features():
    """N same-graph requests -> one dispatch; each gets its own columns back."""
    engine, graphs, feats = _setup(n_graphs=1)
    x = feats["g0"]
    reqs = [GraphRequest("g0", x),
            GraphRequest("g0", 3.0 * x),
            GraphRequest("g0", x[:, :5])]
    engine.serve(reqs)
    assert engine.batches_dispatched == 1
    assert engine.requests_served == 3
    direct = make_accel_spmm(graphs["g0"])
    np.testing.assert_allclose(np.asarray(reqs[0].out),
                               np.asarray(direct(x)), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(reqs[1].out),
                               np.asarray(direct(3.0 * x)),
                               atol=1e-4, rtol=1e-4)
    assert reqs[2].out.shape == (graphs["g0"].n_rows, 5)
    np.testing.assert_allclose(np.asarray(reqs[2].out),
                               np.asarray(direct(x[:, :5])),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.slow
def test_batch_splitting_respects_max_graphs():
    engine, graphs, feats = _setup(n_graphs=5, max_graphs_per_batch=2)
    reqs = [GraphRequest(gid, feats[gid]) for gid in graphs]
    engine.serve(reqs)
    assert engine.batches_dispatched == 3  # ceil(5 / 2)
    for r in reqs:
        direct = make_accel_spmm(graphs[r.graph_id])(feats[r.graph_id])
        np.testing.assert_allclose(np.asarray(r.out), np.asarray(direct),
                                   atol=1e-4, rtol=1e-4)


def test_unknown_graph_rejected():
    engine, _, feats = _setup(n_graphs=1)
    with pytest.raises(KeyError, match="not registered"):
        engine.serve([GraphRequest("nope", feats["g0"])])


def test_bad_feature_shape_rejected():
    engine, graphs, _ = _setup(n_graphs=1)
    wrong = jnp.zeros((graphs["g0"].n_rows + 1, 4), jnp.float32)
    with pytest.raises(ValueError, match="expected"):
        engine.serve([GraphRequest("g0", wrong)])


def test_malformed_request_fails_before_any_dispatch():
    """Validation is all-or-nothing: a bad request in a later batch must not
    leave earlier batches served and counters mutated."""
    engine, graphs, feats = _setup(n_graphs=3, max_graphs_per_batch=1)
    bad = jnp.zeros((5, 5), jnp.float32)
    reqs = [GraphRequest("g0", feats["g0"]),
            GraphRequest("g1", feats["g1"]),
            GraphRequest("g2", bad)]
    with pytest.raises(ValueError, match="expected"):
        engine.serve(reqs)
    assert engine.batches_dispatched == 0
    assert engine.requests_served == 0
    assert all(r.out is None for r in reqs)


def test_serve_does_not_rehash_registered_graphs(monkeypatch):
    """Steady-state dispatches must not recompute the content hash."""
    import repro.core.plan_cache as pc
    engine, graphs, feats = _setup(n_graphs=2)

    def boom(_g):
        raise AssertionError("content hash recomputed on the serve hot path")
    monkeypatch.setattr(pc, "graph_content_hash", boom)
    reqs = [GraphRequest(gid, feats[gid]) for gid in graphs]
    engine.serve(reqs)
    assert all(r.out is not None for r in reqs)


def test_stats_accumulate_and_cache_is_shared():
    shared = PlanCache(capacity=8)
    engine = GraphServeEngine(cache=shared, backend="blocked")
    g = gcn_normalize(make_powerlaw_csr(n=70, seed=9))
    engine.register_graph("a", g)
    x = jnp.ones((g.n_rows, 4), jnp.float32)
    engine.serve([GraphRequest("a", x)])
    engine.serve([GraphRequest("a", x)])
    st = engine.stats()
    assert st["requests_served"] == 2
    assert st["batches_dispatched"] == 2
    assert st["rows_served"] == 2 * g.n_rows
    assert st["total_serve_s"] > 0 and st["rows_per_s"] > 0
    assert st["cache_builds"] == 1 and st["cache_hits"] >= 2
    # the same external cache also serves non-engine callers without rebuild
    make_accel_spmm(g, plan_cache=shared)
    assert shared.builds == 1


def test_reregister_same_content_is_noop_hit():
    engine, graphs, _ = _setup(n_graphs=1)
    assert engine.cache.builds == 1
    engine.register_graph("g0", graphs["g0"])
    assert engine.cache.builds == 1 and engine.cache.hits >= 1


def test_serve_one_convenience():
    engine, graphs, feats = _setup(n_graphs=1)
    out = engine.serve_one("g0", feats["g0"])
    direct = make_accel_spmm(graphs["g0"])(feats["g0"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(direct),
                               atol=1e-4, rtol=1e-4)


# ------------------------------------------------------- routing + latency
def _large_mix_engine(backend):
    engine = GraphServeEngine(backend=backend)
    graphs = {"big": make_wide_csr(500, 20_000, 1_500, seed=1)}
    for i in range(3):
        graphs[f"s{i}"] = gcn_normalize(make_powerlaw_csr(n=80 + 20 * i,
                                                          seed=2 + i))
    for gid, g in graphs.items():
        engine.register_graph(gid, g)
    rng = np.random.default_rng(0)
    reqs = [GraphRequest(gid, jnp.asarray(
        rng.normal(size=(g.n_cols, 8)), jnp.float32))
        for gid, g in graphs.items()]
    return engine, graphs, reqs


@pytest.mark.slow
def test_engine_routes_oversized_batch_to_hbm():
    """Acceptance: a batch mixing one n_cols=20k graph with small graphs
    dispatches through the engine, routes to the HBM-gather backend, and
    matches the per-graph blocked oracle to <= 1e-5."""
    engine, graphs, reqs = _large_mix_engine("auto")
    engine.serve(reqs)
    st = engine.stats()
    assert st["routed_hbm"] == 1, "oversized batch must take the HBM path"
    assert st["routed_resident"] == st["routed_windowed"] == 0
    assert engine.last_decision.backend == "hbm"
    d = engine.last_decision
    assert d.vmem_bytes <= d.total_budget_bytes, \
        "dispatch exceeds the per-call VMEM estimate budget"
    for r in reqs:
        oracle = make_accel_spmm(graphs[r.graph_id], backend="blocked")(r.x)
        np.testing.assert_allclose(np.asarray(r.out), np.asarray(oracle),
                                   atol=1e-5, rtol=1e-5)


def test_engine_forced_resident_raises_budget_error():
    """Acceptance: backend='pallas' on the same oversized batch raises the
    budget error instead of silently compiling, serving nothing."""
    from repro.kernels.router import VmemBudgetError
    engine, _, reqs = _large_mix_engine("pallas")
    with pytest.raises(VmemBudgetError, match="VMEM budget"):
        engine.serve(reqs)
    assert engine.batches_dispatched == 0
    assert all(r.out is None for r in reqs)


def test_engine_small_batches_route_resident():
    engine, graphs, feats = _setup(n_graphs=3, backend="auto")
    engine.serve([GraphRequest(gid, feats[gid]) for gid in graphs])
    st = engine.stats()
    assert st["routed_resident"] == 1
    assert st["routed_hbm"] == st["routed_windowed"] == 0


def test_blocked_backend_counts_as_blocked_dispatch():
    engine, graphs, feats = _setup(n_graphs=1, backend="blocked")
    engine.serve([GraphRequest("g0", feats["g0"])])
    assert engine.stats()["routed_blocked"] == 1


def test_per_request_latency_includes_queue_wait():
    """Requests answered by later dispatches of one serve() call must report
    strictly larger enqueue->answer latency than the first dispatch; the
    per-dispatch kernel time accumulates separately."""
    engine, graphs, feats = _setup(n_graphs=3, max_graphs_per_batch=1)
    reqs = [GraphRequest(gid, feats[gid]) for gid in graphs]
    engine.serve(reqs)
    assert engine.batches_dispatched == 3
    lat = [r.latency_s for r in reqs]
    assert all(l is not None and l > 0 for l in lat)
    assert lat[0] < lat[1] < lat[2], "later dispatches waited in queue"
    st = engine.stats()
    # queue wait means summed request latency exceeds summed kernel time
    assert engine.total_request_latency_s > st["total_serve_s"]
    assert st["avg_dispatch_s"] > 0
    assert st["avg_request_latency_s"] >= st["avg_dispatch_s"]


def test_block_padding_counters_visible():
    engine, graphs, feats = _setup(n_graphs=2)  # default bucket tiers from 8
    engine.serve([GraphRequest(gid, feats[gid]) for gid in graphs])
    st = engine.stats()
    assert st["live_blocks"] > 0
    assert st["padded_blocks"] >= st["live_blocks"]
    # power-of-two tiers bound waste by 2x (plus the min-tier floor of 8)
    assert st["padded_blocks"] < 2 * max(st["live_blocks"], 8)
    assert st["block_pad_ratio"] == st["padded_blocks"] / st["live_blocks"]


def test_bad_backend_rejected():
    with pytest.raises(ValueError, match="backend must be"):
        GraphServeEngine(backend="segment")


# ------------------------------------------------- continuous batching
def test_submit_future_matches_serve_one():
    engine, graphs, feats = _setup(n_graphs=1)
    fut = engine.submit("g0", feats["g0"])
    out = fut.result(timeout=60)
    direct = make_accel_spmm(graphs["g0"])(feats["g0"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(direct),
                               atol=1e-4, rtol=1e-4)
    engine.close()


def test_submit_validates_synchronously():
    engine, graphs, _ = _setup(n_graphs=1)
    with pytest.raises(KeyError, match="not registered"):
        engine.submit("nope", jnp.zeros((3, 3), jnp.float32))
    with pytest.raises(ValueError, match="expected"):
        engine.submit("g0", jnp.zeros((graphs["g0"].n_rows + 1, 4),
                                      jnp.float32))


def test_deadline_flush_fires_for_single_queued_request():
    """A lone submit() must be answered after ~max_wait_ms, not hang waiting
    for co-batchable traffic."""
    engine, graphs, feats = _setup(n_graphs=1, max_wait_ms=20.0)
    out = engine.submit("g0", feats["g0"]).result(timeout=60)
    assert out.shape == feats["g0"].shape
    st = engine.stats()
    assert st["sched_flush_deadline"] == 1
    assert st["sched_flush_size"] == 0
    engine.close()


def test_multithreaded_submit_parity_with_serve():
    """Satellite acceptance: concurrent submit() answers match synchronous
    serve() — same values, ORIGINAL row order — and cross-caller requests
    coalesce into shared fused dispatches."""
    engine, graphs, feats = _setup(n_graphs=3, max_wait_ms=60.0)
    n_threads, per_thread = 4, 6
    futs = [[None] * per_thread for _ in range(n_threads)]

    def caller(t):
        for k in range(per_thread):
            gid = f"g{(t + k) % len(graphs)}"
            futs[t][k] = (gid, float(t + 1),
                          engine.submit(gid, feats[gid] * (t + 1)))

    threads = [threading.Thread(target=caller, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()

    oracles = {gid: make_accel_spmm(graphs[gid]) for gid in graphs}
    for t in range(n_threads):
        for gid, scalef, fut in futs[t]:
            got = fut.result(timeout=120)
            want = oracles[gid](feats[gid] * scalef)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-4, rtol=1e-4)
    st = engine.stats()
    assert st["requests_served"] == n_threads * per_thread
    # the whole point: fewer dispatches than requests, multiple graphs per
    # fused dispatch (concurrent callers shared batches)
    assert st["batches_dispatched"] < n_threads * per_thread
    assert st["requests_per_batch"] > 1.0
    assert st["graphs_per_dispatch"] > 1.0
    engine.close()


def test_sync_serve_coalesces_with_async_submitters():
    """serve() is a wrapper over the same queue: its requests and a
    concurrent submit() can share one flush."""
    engine, graphs, feats = _setup(n_graphs=2, max_wait_ms=25.0)
    results = {}

    def sync_caller():
        reqs = [GraphRequest("g0", feats["g0"])]
        engine.serve(reqs)
        results["sync"] = reqs[0].out

    def async_caller():
        results["async"] = engine.submit("g1", feats["g1"]).result(timeout=60)

    ts = [threading.Thread(target=sync_caller),
          threading.Thread(target=async_caller)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    for gid, key in (("g0", "sync"), ("g1", "async")):
        want = make_accel_spmm(graphs[gid])(feats[gid])
        np.testing.assert_allclose(np.asarray(results[key]),
                                   np.asarray(want), atol=1e-4, rtol=1e-4)
    engine.close()


def test_feature_bucketing_pads_fused_width_only():
    """Fused same-graph widths round to powers of two for jit reuse; the
    per-request outputs are still exactly the requested widths."""
    engine, graphs, feats = _setup(n_graphs=1)  # feature_bucket=True default
    x = feats["g0"]  # width 16
    reqs = [GraphRequest("g0", x), GraphRequest("g0", x[:, :5]),
            GraphRequest("g0", 2.0 * x[:, :7])]   # fused 28 -> padded 32
    engine.serve(reqs)
    assert engine.batches_dispatched == 1
    direct = make_accel_spmm(graphs["g0"])
    np.testing.assert_allclose(np.asarray(reqs[1].out),
                               np.asarray(direct(x[:, :5])),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(reqs[2].out),
                               np.asarray(direct(2.0 * x[:, :7])),
                               atol=1e-4, rtol=1e-4)
    assert reqs[1].out.shape[1] == 5 and reqs[2].out.shape[1] == 7
