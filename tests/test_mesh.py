"""launch/mesh: host-mesh validation errors + the fleet graph meshes."""
import jax
import pytest

from repro.launch.mesh import graph_mesh, make_host_mesh, multihost_graph_mesh


def test_make_host_mesh_default():
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.size == len(jax.devices())


def test_make_host_mesh_indivisible_raises_value_error():
    n = len(jax.devices())
    bad = n + 1   # never divides n (n >= 1)
    with pytest.raises(ValueError) as ei:
        make_host_mesh(model=bad)
    msg = str(ei.value)
    assert str(n) in msg and f"model={bad}" in msg, \
        "error must carry the device/model counts"


def test_make_host_mesh_nonpositive_model_raises():
    with pytest.raises(ValueError):
        make_host_mesh(model=0)


def test_graph_mesh_default_spans_all_devices():
    mesh = graph_mesh()
    assert mesh.axis_names == ("dev",)
    assert mesh.devices.size == len(jax.devices())


def test_multihost_graph_mesh_single_process_degenerates():
    """On one process the global mesh == graph_mesh(): every visible
    device on one flat 'dev' axis (the 2-process case is covered by the
    subprocess test in test_multihost.py)."""
    mesh = multihost_graph_mesh()
    assert mesh.axis_names == ("dev",)
    assert mesh.devices.size == len(jax.devices())
    assert list(mesh.devices.flat) == list(graph_mesh().devices.flat)


def test_graph_mesh_prefix_and_bounds():
    mesh = graph_mesh(1)
    assert mesh.devices.size == 1
    assert mesh.devices.flat[0] == jax.devices()[0]
    with pytest.raises(ValueError):
        graph_mesh(0)
    with pytest.raises(ValueError):
        graph_mesh(len(jax.devices()) + 1)
