"""The serving mutation path: ``mutate()`` end to end through the engine.

Covers the versioned-lifecycle acceptance criteria:

* a mutation publishes a new plan version and later reads observe it;
* mutations racing live read traffic never fail a read, never block it,
  and never tear it — every answer equals the SpMM of SOME version in
  the published chain (pre- or post-publish, never a mix);
* version pins and retired plans drain to zero once traffic stops;
* a bad delta fails only its own mutation future, not the flush's reads;
* the multihost engine converges: a mutation on one host broadcasts the
  delta sequence, both hosts end at the same (key, version), and both
  serve the post-delta graph (in-process two-host fixture — real peer
  TCP, no subprocesses).
"""
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.graph import gcn_normalize
from repro.core.plan_repair import EdgeDelta
from repro.serve.graph_engine import GraphServeEngine

from conftest import make_powerlaw_csr


def _dense(g):
    a = np.zeros((g.n_rows, g.n_cols), np.float64)
    row = np.repeat(np.arange(g.n_rows), np.diff(g.rowptr))
    np.add.at(a, (row, g.colidx.astype(np.int64)), g.values.astype(np.float64))
    return a


def _delta(g, seed, k=3):
    """A small mixed delta valid against ``g``."""
    rng = np.random.default_rng(seed)
    eids = rng.choice(g.nnz, k, replace=False)
    rows = rng.integers(0, g.n_rows, k)
    return EdgeDelta(
        insert_src=rows, insert_dst=rng.integers(0, g.n_cols, k),
        insert_val=rng.normal(size=k).astype(np.float32),
        delete_src=np.searchsorted(g.rowptr, eids, side="right") - 1,
        delete_dst=g.colidx[eids],
        on_duplicate="replace", on_missing="ignore")


def _drain(engine, timeout=5.0):
    """Poll until version pins and retired plans drain (a resolved future
    only means the answer is out — the flush thread's finally-unpin can
    still be in flight for a moment)."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        s = engine.stats()
        if s["cache_pins"] == 0 and s["cache_retired_live"] == 0:
            return s
        time.sleep(0.01)
    raise AssertionError(f"pins/retired never drained: {engine.stats()}")


def test_mutate_publishes_and_serves_new_version():
    engine = GraphServeEngine(backend="blocked")
    g0 = gcn_normalize(make_powerlaw_csr(n=70, seed=0))
    engine.register_graph("g", g0)
    assert engine.graph_version("g") == 0

    delta = _delta(g0, seed=1)
    g1 = delta.apply(g0)
    info = engine.mutate("g", delta).result(timeout=30)
    assert info["version"] == 1 and info["graph_id"] == "g"
    assert engine.graph_version("g") == 1
    assert engine.mutations_applied == 1
    assert engine.plan_repairs + engine.plan_rebuilds == 1

    x = np.random.default_rng(2).normal(size=(g1.n_cols, 5))
    out = engine.submit("g", jnp.asarray(x, jnp.float32)).result(timeout=30)
    np.testing.assert_allclose(np.asarray(out), _dense(g1) @ x,
                               atol=1e-3, rtol=1e-3)
    _drain(engine)
    engine.close()


def test_sequential_mutations_chain_versions():
    engine = GraphServeEngine(backend="blocked")
    g = gcn_normalize(make_powerlaw_csr(n=60, seed=3))
    engine.register_graph("g", g)
    for i in range(4):
        delta = _delta(g, seed=10 + i)
        g = delta.apply(g)
        info = engine.mutate("g", delta).result(timeout=30)
        assert info["version"] == i + 1
    x = np.random.default_rng(0).normal(size=(g.n_cols, 4))
    out = engine.submit("g", jnp.asarray(x, jnp.float32)).result(timeout=30)
    np.testing.assert_allclose(np.asarray(out), _dense(g) @ x,
                               atol=1e-3, rtol=1e-3)
    _drain(engine)
    engine.close()


def test_bad_delta_fails_only_its_mutation():
    engine = GraphServeEngine(backend="blocked")
    g = gcn_normalize(make_powerlaw_csr(n=50, seed=5))
    engine.register_graph("g", g)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(g.n_cols, 3)),
                    jnp.float32)
    # a delete of a non-existent edge with strict on_missing: the delta is
    # well-formed at submit time, fails during apply
    bad = EdgeDelta(delete_src=[0], delete_dst=[g.n_cols - 1])
    assert _dense(g)[0, g.n_cols - 1] == 0.0
    read = engine.submit("g", x)
    fut = engine.mutate("g", bad)
    with pytest.raises(ValueError):
        fut.result(timeout=30)
    # the read (same flush or not) is unaffected, version unchanged
    np.testing.assert_allclose(np.asarray(read.result(timeout=30)),
                               _dense(g) @ np.asarray(x), atol=1e-3,
                               rtol=1e-3)
    assert engine.graph_version("g") == 0
    _drain(engine)
    engine.close()


@pytest.mark.slow
def test_mutations_racing_reads_are_never_torn():
    """The hammer: reader threads submit continuously while a writer
    publishes a chain of versions. Every answer must equal the SpMM of
    some published version — pre- or post-publish, never a blend — and
    no read may ever fail."""
    engine = GraphServeEngine(backend="blocked", max_wait_ms=1.0)
    g0 = gcn_normalize(make_powerlaw_csr(n=80, seed=7))
    engine.register_graph("g", g0)

    n_versions = 5
    chain = [g0]
    for i in range(n_versions):
        chain.append(_delta(chain[-1], seed=100 + i).apply(chain[-1]))
    x = np.random.default_rng(9).normal(size=(g0.n_cols, 4))
    refs = [_dense(g) @ x for g in chain]
    xj = jnp.asarray(x, jnp.float32)

    stop = threading.Event()
    failures = []
    matched_versions = set()

    def reader():
        while not stop.is_set():
            try:
                y = np.asarray(engine.submit("g", xj).result(timeout=30))
            except Exception as e:  # noqa: BLE001 — a failed read IS the bug
                failures.append(repr(e))
                return
            errs = [float(np.max(np.abs(y - r))) for r in refs]
            best = int(np.argmin(errs))
            if errs[best] > 1e-3:
                failures.append(f"answer matches no version: errs={errs}")
                return
            matched_versions.add(best)

    readers = [threading.Thread(target=reader) for _ in range(3)]
    for t in readers:
        t.start()
    try:
        for i in range(n_versions):
            delta = _delta(chain[i], seed=100 + i)
            info = engine.mutate("g", delta).result(timeout=30)
            assert info["version"] == i + 1
            time.sleep(0.02)     # let readers overlap each published version
    finally:
        stop.set()
        for t in readers:
            t.join(timeout=30)
    assert not failures, failures[:3]
    # the race was real: reads landed on several distinct versions
    assert len(matched_versions) >= 2, matched_versions
    assert engine.graph_version("g") == n_versions
    s = _drain(engine)
    assert s["cache_publishes"] >= n_versions
    engine.close()


# ----------------------------------------------------------- multihost

def _two_host_engines():
    from repro.distributed.multihost import MultihostContext, PeerClient
    from repro.serve.fleet import MultihostGraphEngine

    devs = list(jax.local_devices())

    def ctx(i):
        return MultihostContext(process_index=i, process_count=2,
                                coordinator=None, local_devices=devs,
                                global_devices=devs)

    a = MultihostGraphEngine(context=ctx(0), serve_port=0,
                             peer_addresses={}, backend="blocked")
    b = MultihostGraphEngine(context=ctx(1), serve_port=0,
                             peer_addresses={}, backend="blocked")
    a.peers = {1: PeerClient(("127.0.0.1", b.server.port),
                             process_index=0, epoch=0)}
    b.peers = {0: PeerClient(("127.0.0.1", a.server.port),
                             process_index=1, epoch=0)}
    a.connect_peers()
    b.connect_peers()
    return a, b


@pytest.mark.slow
def test_multihost_mutation_converges_both_hosts():
    a, b = _two_host_engines()
    try:
        rng = np.random.default_rng(0)
        pool = {}
        for i in range(6):   # enough graphs that consistent hashing puts
            gid = f"g{i}"    # at least one on each host
            g = gcn_normalize(make_powerlaw_csr(n=50 + 10 * i, seed=i))
            pool[gid] = g
            a.register_graph(gid, g)
            b.register_graph(gid, g)
        all_owners = {gid: a.directory.place(a._keys[gid]).host
                      for gid in pool}
        assert set(all_owners.values()) == {0, 1}, all_owners
        # mutate one graph per owning host: exercises both the owner-repair
        # path (a owns it) and the non-owner rebind path (b owns it)
        picks = {h: next(g for g, o in all_owners.items() if o == h)
                 for h in (0, 1)}
        graphs = {gid: pool[gid] for gid in picks.values()}
        owners = {gid: all_owners[gid] for gid in graphs}

        # single writer (host a) mutates BOTH graphs — one it owns, one
        # owned by the peer — exercising owner-repair and non-owner rebind
        for gid, g in list(graphs.items()):
            delta = _delta(g, seed=42)
            graphs[gid] = delta.apply(g)
            info = a.mutate(gid, delta).result(timeout=60)
            assert info["version"] == 1

        for gid in graphs:
            # identical chained key and version on both hosts
            assert a._keys[gid] == b._keys[gid]
            assert a._versions[gid] == b._versions[gid] == 1
            assert a.directory.place(a._keys[gid]).host == owners[gid]
        assert a.mutation_broadcasts == 2
        assert b.remote_mutations == 2
        assert b.mutation_broadcast_failures == 0

        # both hosts serve the POST-delta graphs (forwarding included)
        for eng in (a, b):
            for gid, g in graphs.items():
                x = rng.normal(size=(g.n_cols, 4))
                out = eng.submit(gid, jnp.asarray(x, jnp.float32)).result(
                    timeout=60)
                np.testing.assert_allclose(np.asarray(out), _dense(g) @ x,
                                           atol=1e-3, rtol=1e-3)
    finally:
        a.close()
        b.close()


@pytest.mark.slow
def test_multihost_version_fork_guard():
    """Two writers racing the same graph must not silently diverge: a
    replayed broadcast against the wrong base version raises."""
    a, b = _two_host_engines()
    try:
        g = gcn_normalize(make_powerlaw_csr(n=50, seed=1))
        a.register_graph("g", g)
        b.register_graph("g", g)
        delta = _delta(g, seed=3)
        a.mutate("g", delta).result(timeout=60)   # both hosts now at v1
        with pytest.raises(RuntimeError, match="fork"):
            b._apply_deltas_local("g", [_delta(delta.apply(g), seed=4)],
                                  expect_base=0)  # stale writer base
    finally:
        a.close()
        b.close()
