"""End-to-end behaviour: GCN training converges through the paper's operator;
LM training reduces loss; fault-tolerant loop resumes bit-identically."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_reduced
from repro.core.graph import gcn_normalize
from repro.data.graphs import make_power_law_graph, node_features, node_labels
from repro.data.tokens import token_batch_fn
from repro.models.gcn import GraphOp, gcn_loss, init_gcn
from repro.train.loop import train_loop
from repro.train.step import init_train_state, make_train_step


@pytest.mark.parametrize("variant", ["gcn", "sage", "gin"])
def test_gcn_training_reduces_loss(variant):
    n, d, classes = 120, 16, 4
    g = gcn_normalize(make_power_law_graph(n, 600, seed=0))
    aggr = GraphOp.build(g, backend="blocked")
    X = jnp.asarray(node_features(n, d, 0))
    y = jnp.asarray(node_labels(n, classes, 0))
    params = init_gcn(jax.random.PRNGKey(0), [d, 32, classes], variant)

    def loss_fn(p):
        return gcn_loss(p, aggr, X, y, variant)
    vg = jax.jit(jax.value_and_grad(loss_fn))
    l0 = float(loss_fn(params))
    lr = 0.05
    for _ in range(60):
        l, grads = vg(params)
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    l1 = float(loss_fn(params))
    # random labels: pure-aggregation GCN memorizes slower than SAGE/GIN
    # (no self path), so the gate is a firm decrease, not a fixed ratio.
    assert l1 < l0 - 0.1, f"{variant}: {l0} -> {l1}"


def test_gcn_gradient_flows_through_spmm():
    n, d = 60, 8
    g = gcn_normalize(make_power_law_graph(n, 240, seed=1))
    aggr = GraphOp.build(g, backend="blocked")
    X = jnp.asarray(node_features(n, d, 1))
    params = init_gcn(jax.random.PRNGKey(1), [d, 8, 3], "gcn")
    grads = jax.grad(lambda p: gcn_loss(p, aggr, X,
                                        jnp.zeros(n, jnp.int32)))(params)
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


def test_lm_train_loss_decreases():
    cfg = get_reduced("phi3-mini-3.8b")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, peak_lr=5e-3, warmup=5, total=100,
                                   loss_chunk=16, q_chunk=16, kv_chunk=16))
    bf = token_batch_fn(batch=4, seq=32, vocab=cfg.vocab, seed=0)
    losses = []
    for s in range(25):
        state, m = step(state, {k: jnp.asarray(v) for k, v in bf(s).items()})
        losses.append(float(m["ce"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_fault_tolerant_resume_bit_identical(tmp_path):
    """Crash mid-run, restart from checkpoint: final state equals an
    uninterrupted run exactly (stateless data + deterministic step)."""
    cfg = get_reduced("qwen1.5-32b")
    bf_np = token_batch_fn(batch=2, seq=16, vocab=cfg.vocab, seed=1)
    def bf(s):
        return {k: jnp.asarray(v) for k, v in bf_np(s).items()}
    step = jax.jit(make_train_step(cfg, loss_chunk=16, q_chunk=16, kv_chunk=16))

    def fresh():
        return init_train_state(cfg, jax.random.PRNGKey(3))

    ref = train_loop(state=fresh(), train_step=step, batch_fn=bf, n_steps=8,
                     ckpt=None, log_every=100, log_fn=lambda *_: None)

    ck = CheckpointManager(str(tmp_path), keep=2)
    with pytest.raises(RuntimeError):
        train_loop(state=fresh(), train_step=step, batch_fn=bf, n_steps=8,
                   ckpt=ck, ckpt_every=3, crash_at=5, log_every=100,
                   log_fn=lambda *_: None)
    assert ck.latest_step() == 3
    out = train_loop(state=fresh(), train_step=step, batch_fn=bf, n_steps=8,
                     ckpt=ck, ckpt_every=3, log_every=100, log_fn=lambda *_: None)
    for a, b in zip(jax.tree_util.tree_leaves(ref["state"].params),
                    jax.tree_util.tree_leaves(out["state"].params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_straggler_accounting():
    import time

    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 8:
            time.sleep(0.25)
        return state, {"loss": jnp.asarray(1.0)}

    out = train_loop(state={}, train_step=slow_step,
                     batch_fn=lambda s: {}, n_steps=10, log_every=100,
                     straggler_factor=3.0, log_fn=lambda *_: None)
    assert out["stragglers"] >= 1
