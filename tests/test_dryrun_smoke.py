"""Dry-run machinery on 8 fake host devices (subprocess so the XLA flag does
not leak into other tests): reduced configs x all shape kinds x small mesh."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    import jax
    from repro.configs import get_reduced
    from repro.configs.base import ShapeConfig
    from repro.launch.dryrun import lower_and_compile, _cost_vector

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    out = {}
    cells = [
        ("qwen1.5-32b", ShapeConfig("t", "train", 64, 8)),
        ("gemma2-27b", ShapeConfig("p", "prefill", 64, 4)),
        ("deepseek-moe-16b", ShapeConfig("t", "train", 64, 8)),
        ("mamba2-780m", ShapeConfig("d", "decode", 64, 8)),
        ("zamba2-7b", ShapeConfig("d", "decode", 64, 8)),
        ("hubert-xlarge", ShapeConfig("t", "train", 64, 8)),
    ]
    for name, shape in cells:
        cfg = get_reduced(name)
        lowered, compiled, dt = lower_and_compile(
            cfg, shape, mesh, chunks={"q_chunk": 16, "kv_chunk": 16,
                                      "loss_chunk": 16, "ssd_chunk": 8})
        cv = _cost_vector(compiled)
        ma = compiled.memory_analysis()
        out[name + ":" + shape.kind] = {
            "flops": cv["flops"], "coll": cv["coll"],
            "temp": ma.temp_size_in_bytes}
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_dryrun_small_mesh_all_families():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
                       env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert len(out) == 6
    for k, v in out.items():
        assert v["flops"] > 0, k
