"""Online partition autotuner: PlanTuner state machine (fake clock,
fixed candidates — fully deterministic), the candidate generator, and the
GraphServeEngine shadow-rollout integration (promotion through the
version chain, tuned-config spill/reload)."""
import dataclasses
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import gcn_normalize
from repro.core.plan_cache import (PartitionConfig, PlanCache,
                                   build_partition_plan)
from repro.core.spmm import make_accel_spmm
from repro.serve.graph_engine import GraphServeEngine
from repro.tuning import (PlanTuner, TuningCandidate, default_candidates,
                          staircase_warp_nzs, tune_offline)

from conftest import make_powerlaw_csr

BASE = PartitionConfig()


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _fixed_candidates(n=2):
    cfgs = [dataclasses.replace(BASE, max_warp_nzs=BASE.max_warp_nzs // 2),
            dataclasses.replace(BASE, max_rows_per_block=BASE.deg_bound),
            dataclasses.replace(
                BASE, warp_nzs_table=staircase_warp_nzs(
                    BASE.max_block_warps, BASE.max_warp_nzs))]
    return [TuningCandidate(config=c, label=f"c{i}")
            for i, c in enumerate(cfgs[:n])]


def _hot_tuner(clock, **kw):
    kw.setdefault("hot_rate", 10.0)
    kw.setdefault("shadow_fraction", 1.0)
    kw.setdefault("win_streak", 2)
    kw.setdefault("min_improvement", 0.02)
    kw.setdefault("max_trials", 4)
    kw.setdefault("candidates", _fixed_candidates())
    return PlanTuner(now_fn=clock, halflife_s=1.0, **kw)


def _heat(tuner, gid="g", n=100):
    tuner.observe(gid, n)   # burst >> hot_rate * halflife / ln2


# ---------------------------------------------------------------------------
# pure policy: deterministic under the fake clock
# ---------------------------------------------------------------------------
def test_cold_graph_never_shadowed():
    clock = FakeClock()
    tuner = _hot_tuner(clock)
    tuner.observe("g", 1)
    for _ in range(10):
        assert tuner.next_shadow("g", BASE) is None
    assert tuner.stats()["tracked"] == 0


def test_hot_graph_enters_tuning_and_cools_off_clockwise():
    clock = FakeClock()
    tuner = _hot_tuner(clock)
    _heat(tuner)
    assert tuner.next_shadow("g", BASE) is not None
    # an UNSEEN graph whose rate decayed to ~0 stays untracked
    clock.t += 1000.0
    tuner.observe("g2", 1)
    assert tuner.next_shadow("g2", BASE) is None


def test_shadow_stride_is_deterministic():
    clock = FakeClock()
    tuner = _hot_tuner(clock, shadow_fraction=0.25)
    _heat(tuner)
    picks = [tuner.next_shadow("g", BASE) is not None for _ in range(12)]
    assert picks == [False, False, False, True] * 3


def test_win_streak_promotes_and_stops_shadowing():
    clock = FakeClock()
    tuner = _hot_tuner(clock)
    _heat(tuner)
    cand = tuner.next_shadow("g", BASE)
    assert tuner.record_shadow("g", cand, 1.0, 0.5) is None
    winner = tuner.record_shadow("g", cand, 1.0, 0.5)
    assert winner is cand
    tuner.confirm_promoted("g")
    assert tuner.describe("g")["status"] == "promoted"
    assert tuner.next_shadow("g", BASE) is None
    s = tuner.stats()
    assert s["promotions"] == 1 and s["wins"] == 2


def test_loss_resets_the_streak():
    clock = FakeClock()
    tuner = _hot_tuner(clock, max_trials=10)
    _heat(tuner)
    cand = tuner.next_shadow("g", BASE)
    assert tuner.record_shadow("g", cand, 1.0, 0.5) is None     # win
    assert tuner.record_shadow("g", cand, 1.0, 0.999) is None   # loss (< 2%)
    assert tuner.describe("g")["streak"] == 0
    # needs a fresh full streak after the loss
    assert tuner.record_shadow("g", cand, 1.0, 0.5) is None
    assert tuner.record_shadow("g", cand, 1.0, 0.5) is cand


def test_max_trials_advances_then_exhausts():
    clock = FakeClock()
    tuner = _hot_tuner(clock, max_trials=2, win_streak=2)
    _heat(tuner)
    c0 = tuner.next_shadow("g", BASE)
    tuner.record_shadow("g", c0, 1.0, 2.0)
    tuner.record_shadow("g", c0, 1.0, 2.0)      # c0 dropped
    c1 = tuner.next_shadow("g", BASE)
    assert c1 is not c0 and c1.label == "c1"
    tuner.record_shadow("g", c1, 1.0, 2.0)
    tuner.record_shadow("g", c1, 1.0, 2.0)      # list exhausted
    assert tuner.next_shadow("g", BASE) is None
    assert tuner.describe("g")["status"] == "exhausted"
    assert tuner.stats()["exhausted"] == 1


def test_candidate_failure_drops_candidate():
    clock = FakeClock()
    tuner = _hot_tuner(clock)
    _heat(tuner)
    c0 = tuner.next_shadow("g", BASE)
    tuner.candidate_failed("g", c0)
    assert tuner.next_shadow("g", BASE).label == "c1"
    assert tuner.stats()["candidate_failures"] == 1


def test_stale_shadow_result_is_ignored():
    clock = FakeClock()
    tuner = _hot_tuner(clock)
    _heat(tuner)
    c0 = tuner.next_shadow("g", BASE)
    tuner.candidate_failed("g", c0)             # moved on to c1
    assert tuner.record_shadow("g", c0, 1.0, 0.1) is None
    assert tuner.stats()["comparisons"] == 0


def test_reset_reenters_tuning_from_scratch():
    clock = FakeClock()
    tuner = _hot_tuner(clock)
    _heat(tuner)
    c0 = tuner.next_shadow("g", BASE)
    tuner.record_shadow("g", c0, 1.0, 0.5)
    tuner.reset("g")
    assert tuner.describe("g") is None
    _heat(tuner)
    again = tuner.next_shadow("g", BASE)
    assert again.label == "c0" and tuner.describe("g")["trials"] == 0


def test_constructor_validation():
    with pytest.raises(ValueError):
        PlanTuner(shadow_fraction=0.0)
    with pytest.raises(ValueError):
        PlanTuner(win_streak=3, max_trials=2)


# ---------------------------------------------------------------------------
# candidate generator
# ---------------------------------------------------------------------------
def test_default_candidates_admissible_and_nondefault():
    from repro.core.partition import validate_warp_nzs_override
    cands = default_candidates(BASE)
    assert len(cands) >= 4
    assert len({c.label for c in cands}) == len(cands)
    for c in cands:
        assert c.config != BASE or c.backend is not None
        if c.config.warp_nzs_table is not None:
            validate_warp_nzs_override(c.config.max_block_warps,
                                       c.config.max_warp_nzs,
                                       c.config.warp_nzs_table)
    # best-guess-first: the halved-slab capacity variant leads the list
    assert cands[0].label == "half-slab"


def test_staircase_table_is_minimal_admissible():
    mbw, mwn = BASE.max_block_warps, BASE.max_warp_nzs
    tab = staircase_warp_nzs(mbw, mwn)
    assert len(tab) == mbw * mwn
    for d, w in enumerate(tab, start=1):
        assert 1 <= w <= mwn and mbw * w >= d
        assert w == 1 or mbw * (w - 1) < d      # cannot shrink further


# ---------------------------------------------------------------------------
# engine integration: shadow rollout end to end
# ---------------------------------------------------------------------------
def _graph():
    return gcn_normalize(make_powerlaw_csr(n=220, seed=7))


def _promote(engine, gid, x, deadline_s=30.0):
    t0 = time.monotonic()
    while engine.stats()["tuned_promotions"] < 1:
        engine.serve_one(gid, x)
        time.sleep(0.005)
        assert time.monotonic() - t0 < deadline_s, \
            f"no promotion: {engine.tuner.describe(gid)}"


def test_engine_promotes_and_serves_correctly():
    g = _graph()
    x = jnp.asarray(np.random.default_rng(0).normal(size=(g.n_cols, 8)),
                    dtype=jnp.float32)
    # min_improvement << 0 makes every comparison a win, so the FIRST
    # candidate promotes after win_streak shadows regardless of timings
    tuner = PlanTuner(hot_rate=0.0, shadow_fraction=1.0, win_streak=2,
                      min_improvement=-100.0, max_trials=4,
                      candidates=_fixed_candidates(1))
    engine = GraphServeEngine(backend="blocked", tuner=tuner)
    try:
        engine.register_graph("hot", g)
        v0 = engine.plan_for("hot").version
        _promote(engine, "hot", x)
        plan = engine.plan_for("hot")
        assert plan.tuned is not None and plan.tuned["label"] == "c0"
        assert plan.config == _fixed_candidates(1)[0].config
        assert plan.version > v0, "promotion must ride the version chain"
        # the tuned plan answers exactly like the reference operator
        out = engine.serve_one("hot", x)
        direct = make_accel_spmm(g)(x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(direct),
                                   atol=1e-4, rtol=1e-4)
        s = engine.stats()
        assert s["tuned_graphs"] == 1 and s["shadow_failures"] == 0
        assert s["tuner_promotions"] == 1
    finally:
        engine.close()


def test_reregister_same_content_keeps_tuned_binding():
    g = _graph()
    x = jnp.asarray(np.random.default_rng(1).normal(size=(g.n_cols, 8)),
                    dtype=jnp.float32)
    tuner = PlanTuner(hot_rate=0.0, shadow_fraction=1.0, win_streak=1,
                      min_improvement=-100.0, max_trials=2,
                      candidates=_fixed_candidates(1))
    engine = GraphServeEngine(backend="blocked", tuner=tuner)
    try:
        engine.register_graph("hot", g)
        _promote(engine, "hot", x)
        tuned_key = engine.plan_for("hot").key
        engine.register_graph("hot", g)     # same content: must be a no-op
        assert engine.plan_for("hot").key == tuned_key
        assert engine.plan_for("hot").tuned is not None
    finally:
        engine.close()


def test_shadow_never_blocks_reads_while_busy():
    """The opportunistic-skip invariant: at most one shadow in flight,
    extra shadow-due dispatches are counted as skipped, never queued."""
    g = _graph()
    x = jnp.asarray(np.random.default_rng(2).normal(size=(g.n_cols, 8)),
                    dtype=jnp.float32)
    tuner = PlanTuner(hot_rate=0.0, shadow_fraction=1.0, win_streak=10 ** 6,
                      min_improvement=10.0, max_trials=10 ** 6,
                      candidates=_fixed_candidates(2))
    engine = GraphServeEngine(backend="blocked", tuner=tuner)
    try:
        engine.register_graph("hot", g)
        for _ in range(30):
            engine.serve_one("hot", x)      # no pacing: worker stays busy
        s = engine.stats()
        assert s["shadow_dispatches"] + s["shadow_skipped"] >= 29
        assert s["tuned_promotions"] == 0
    finally:
        engine.close()


# ---------------------------------------------------------------------------
# tuned configs survive disk spill/reload
# ---------------------------------------------------------------------------
def test_tuned_plan_roundtrips_through_spill(tmp_path):
    cache = PlanCache(capacity=1, save_dir=str(tmp_path))
    cfg = dataclasses.replace(
        BASE, warp_nzs_table=staircase_warp_nzs(BASE.max_block_warps,
                                                BASE.max_warp_nzs))
    g = _graph()
    plan = cache.get_or_build(g, cfg)
    plan.tuned = {"backend": None, "grid_order": "block_major",
                  "label": "wnz-min"}
    cache.get_or_build(gcn_normalize(make_powerlaw_csr(n=150, seed=8)), BASE)
    assert cache.stats()["spills"] == 1     # evicted + spilled the tuned plan

    back = cache.get_or_build(g, cfg)       # disk reload, not a rebuild
    assert cache.stats()["disk_hits"] == 1
    assert back.tuned == plan.tuned
    assert back.key == plan.key
    assert back.key[1].warp_nzs_table == cfg.warp_nzs_table
    for k in ("colidx", "values", "rowloc", "out_row"):
        np.testing.assert_array_equal(np.asarray(back.slabs[k]),
                                      np.asarray(plan.slabs[k]))


# ---------------------------------------------------------------------------
# offline search
# ---------------------------------------------------------------------------
def test_tune_offline_ranks_candidates():
    g = _graph()
    rep = tune_offline(g, feat_dim=8, repeats=1,
                       candidates=_fixed_candidates(2))
    assert {r["label"] for r in rep["candidates"]} == {"c0", "c1"}
    assert all("time_s" in r for r in rep["candidates"])
    assert rep["best"]["label"] in {"c0", "c1"}
    assert rep["base"]["time_s"] > 0


def test_tune_offline_broken_candidate_is_a_result_not_a_crash():
    g = _graph()
    bad = TuningCandidate(config=BASE, backend="no-such-backend",
                          label="broken")
    rep = tune_offline(g, feat_dim=8, repeats=1, candidates=[bad])
    (row,) = rep["candidates"]
    assert row["label"] == "broken" and "error" in row
    assert rep["best"] is None and rep["best_speedup"] == 0.0
