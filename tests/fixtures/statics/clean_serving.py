"""Clean fixture: correct lock discipline and future settlement — the
analyzer must report nothing here."""

import threading
import time
from concurrent.futures import Future


class TinyQueue:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = []

    def _pop_locked(self):
        return self._items.pop() if self._items else None

    def take(self):
        with self._lock:
            return self._pop_locked()

    def put(self, item):
        with self._lock:
            self._items.append(item)
        time.sleep(0)  # blocking OUTSIDE the lock is fine


def settled(flag: bool) -> None:
    fut = Future()
    if flag:
        fut.set_result(1)
    else:
        fut.cancel()
    return None
