"""Well-formed suppression: the violation is acknowledged with a reason,
so the analyzer must report nothing."""

import threading
import time


class SuppressedSleeper:
    def __init__(self):
        self._lock = threading.Lock()

    def tick(self):
        with self._lock:
            time.sleep(0)  # statics: ignore[blocking-call-under-lock] -- fixture: exercises the suppression syntax end to end
