"""Seeded violation: Python `if` on a traced value inside a kernel."""

from jax.experimental import pallas as pl


def _branch_kernel(x_ref, o_ref):
    i = pl.program_id(0)
    if i > 0:  # <- pallas-traced-branch: i is abstract at trace time
        o_ref[i] = x_ref[i]
