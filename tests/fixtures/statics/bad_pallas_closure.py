"""Seeded violation: host numpy arrays captured/built inside a kernel."""

import numpy as np
from jax.experimental import pallas as pl

_TABLE = np.arange(16)


def _closure_kernel(x_ref, o_ref):
    i = pl.program_id(0)
    o_ref[i] = x_ref[i] * _TABLE[0]  # <- pallas-closure-numpy (module array)
    scale = np.ones((8,))  # <- pallas-closure-numpy (built in kernel)
    o_ref[0] = scale[0]
