"""Seeded violation: blocking calls inside a 'with lock:' body."""

import threading
import time


class BlockingUnderLock:
    def __init__(self):
        self._lock = threading.Lock()

    def sleepy(self):
        with self._lock:
            time.sleep(0.1)  # <- blocking-call-under-lock

    def waits_on_future(self, fut):
        with self._lock:
            return fut.result()  # <- blocking-call-under-lock

    def polls_future(self, fut):
        with self._lock:
            return fut.result(timeout=0)  # non-blocking poll: allowed
