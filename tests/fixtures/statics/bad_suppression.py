"""Seeded violation: a suppression with no reason string.  It must not
suppress (the blocking finding still fires) and must itself raise
``bad-suppression``."""

import threading
import time


class BadSuppression:
    def __init__(self):
        self._lock = threading.Lock()

    def tick(self):
        with self._lock:
            time.sleep(0)  # statics: ignore[blocking-call-under-lock]
