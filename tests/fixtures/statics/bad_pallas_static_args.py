"""Seeded violation: non-array params missing from static_argnames."""

import functools

import jax
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def copy_call(x, n_rows: int, *, f_tile=128, interpret=True):
    # n_rows (annotated int) and f_tile (int default) would trace as
    # dynamic values <- pallas-static-args x2
    del n_rows, f_tile
    return pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
