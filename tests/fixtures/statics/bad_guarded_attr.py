"""Seeded violation: guarded attribute touched outside its lock.

The class deliberately shadows the real ``BatchScheduler`` name so the
analyzer's default guarded-attribute registry (``_queues`` -> ``_cond``)
applies to it.
"""

import threading
from collections import deque


class BatchScheduler:
    def __init__(self):
        self._cond = threading.Condition()
        self._queues = {"default": deque()}

    def qsize_atomic(self):
        with self._cond:
            return sum(len(q) for q in self._queues.values())

    def qsize_torn(self):
        return sum(len(q) for q in self._queues.values())  # <- guarded-attr-outside-lock
