"""Seeded violation: *_locked method called outside any lock block."""

import threading


class LeakyQueue:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = []

    def _pop_locked(self):
        return self._items.pop() if self._items else None

    def take_safely(self):
        with self._lock:
            return self._pop_locked()

    def take_racy(self):
        return self._pop_locked()  # <- locked-call-outside-lock
