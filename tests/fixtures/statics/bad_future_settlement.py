"""Seeded violations: a leaked future and a double-settled future."""

from concurrent.futures import Future


def leaky(flag: bool) -> None:
    fut = Future()  # <- future-leak: flag=False path never settles it
    if flag:
        fut.set_result(1)
    return None


def double(flag: bool) -> None:
    fut = Future()  # <- future-double-settle on the flag=True path
    fut.set_result(1)
    if flag:
        fut.set_result(2)
    return None
