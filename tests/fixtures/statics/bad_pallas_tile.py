"""Seeded violation: BlockSpec tile does not divide the padded out dim."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def tiled_copy(x):
    return pl.pallas_call(
        _tile_kernel,
        grid=(3,),
        out_shape=jax.ShapeDtypeStruct((96, 100), jnp.float32),
        # 100 % 64 != 0 <- pallas-tile-divisibility
        out_specs=pl.BlockSpec((32, 64), lambda i: (i, 0)),
    )(x)
