"""Deterministic fallback shim for the `hypothesis` API surface this suite uses.

Activated by ``tests/conftest.py`` ONLY when the real `hypothesis` package is
not importable (e.g. a hermetic container without dev deps). CI installs the
real library via ``requirements-dev.txt`` and never sees this module.

The shim replays each ``@given`` test over ``max_examples`` pseudo-random
draws from a seeded generator — no shrinking, no database, but the same test
bodies execute and real failures still fail. Only the strategies the suite
uses are provided: ``integers``, ``sampled_from``, ``lists``.
"""
from __future__ import annotations

import functools
import inspect
import random

__version__ = "0.0-shim"


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def do_draw(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return SearchStrategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements) -> SearchStrategy:
        elements = list(elements)
        return SearchStrategy(lambda rng: elements[rng.randrange(len(elements))])

    @staticmethod
    def lists(elements: SearchStrategy, min_size: int = 0,
              max_size: int = 10) -> SearchStrategy:
        def draw(rng):
            size = rng.randint(min_size, max_size)
            return [elements.do_draw(rng) for _ in range(size)]
        return SearchStrategy(draw)


strategies = _Strategies()


class settings:
    """Decorator recording max_examples; other kwargs accepted and ignored."""

    def __init__(self, max_examples: int = 20, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._shim_settings = self
        return fn


def given(**strats):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_shim_settings", None)
            n = cfg.max_examples if cfg else 20
            rng = random.Random(0)  # deterministic across runs
            for i in range(n):
                drawn = {k: s.do_draw(rng) for k, s in strats.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"hypothesis-shim example {i}/{n} failed with "
                        f"drawn={drawn}: {e}") from e

        # pytest inspects the signature to resolve fixtures: hide the drawn
        # parameters (and the __wrapped__ escape hatch functools.wraps left).
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        return wrapper
    return decorate


def assume(condition) -> bool:
    """Degenerate assume: silently accept (the suite does not use it)."""
    return bool(condition)


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
