"""Attention paths vs a naive dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (KVCache, _project_qkv, attention_decode,
                                    attention_forward, banded_attention,
                                    chunked_attention, init_attention)
from repro.models.layers import rope_table


def naive(q, k, v, causal=True, window=None, softcap=None, scale=None):
    B, T, H, D = q.shape
    G = H // k.shape[2]
    kk, vv = jnp.repeat(k, G, 2), jnp.repeat(v, G, 2)
    scale = D ** -0.5 if scale is None else scale
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   kk.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(T)
    m = jnp.ones((T, T), bool)
    if causal:
        m &= pos[:, None] >= pos[None, :]
    if window:
        m &= pos[:, None] - pos[None, :] < window
    s = jnp.where(m[None, None], s, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1),
                      vv.astype(jnp.float32))


@pytest.fixture(scope="module")
def qkv():
    B, T, D, H, KH, dh = 2, 64, 32, 4, 2, 8
    p = init_attention(jax.random.PRNGKey(0), D, H, KH, dh, qkv_bias=True,
                       dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, D))
    cos, sin = rope_table(jnp.arange(T), dh, 1e4)
    return p, x, _project_qkv(p, x, H, KH, dh, cos, sin)


@pytest.mark.parametrize("causal,window,softcap,qc,kc", [
    (True, None, None, 16, 16), (True, None, None, 64, 8),
    (True, 16, None, 16, 16), (False, None, None, 8, 32),
    (True, None, 30.0, 16, 16), (True, 24, 50.0, 8, 8),
])
def test_chunked_matches_naive(qkv, causal, window, softcap, qc, kc):
    _, _, (q, k, v) = qkv
    ref = naive(q, k, v, causal, window, softcap)
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap, q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)


@pytest.mark.parametrize("window,qc", [(16, 16), (8, 32), (24, 8)])
def test_banded_matches_naive(qkv, window, qc):
    _, _, (q, k, v) = qkv
    ref = naive(q, k, v, True, window)
    out = banded_attention(q, k, v, window=window, q_chunk=qc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)


def test_decode_matches_prefill(qkv):
    p, x, _ = qkv
    B, T = x.shape[:2]
    H, KH, dh = 4, 2, 8
    full = attention_forward(p, x, n_heads=H, n_kv_heads=KH, d_head=dh,
                             q_chunk=16, kv_chunk=16)
    cache = KVCache.create(B, T, KH, dh, jnp.float32)
    outs = []
    for t in range(T):
        o, cache = attention_decode(p, x[:, t:t + 1], cache, t, n_heads=H,
                                    n_kv_heads=KH, d_head=dh, rope_theta=1e4)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), atol=1e-5)


def test_unrolled_scan_equivalence(qkv):
    """SCAN_UNROLL (roofline probes) must not change numerics."""
    import repro.models.attention as A
    _, _, (q, k, v) = qkv
    base = chunked_attention(q, k, v, q_chunk=16, kv_chunk=16)
    A.SCAN_UNROLL = True
    try:
        unrolled = chunked_attention(q, k, v, q_chunk=16, kv_chunk=16)
    finally:
        A.SCAN_UNROLL = False
    np.testing.assert_allclose(np.asarray(base), np.asarray(unrolled), atol=1e-6)


def test_bf16_einsums_flag_tolerance(qkv):
    """BF16_EINSUMS (§Perf lever) stays within bf16 tolerance of fp32 math."""
    import repro.models.attention as A
    _, _, (q, k, v) = qkv
    base = chunked_attention(q, k, v, q_chunk=16, kv_chunk=16)
    A.BF16_EINSUMS = True
    try:
        fast = chunked_attention(q, k, v, q_chunk=16, kv_chunk=16)
    finally:
        A.BF16_EINSUMS = False
    np.testing.assert_allclose(np.asarray(fast), np.asarray(base), atol=0.05,
                               rtol=0.05)
