"""Serving engine: batched generation, determinism, slot masking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import lm
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_reduced("phi3-mini-3.8b")
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, batch=4, max_seq=64, eos_id=-1)


def test_batched_generation(engine):
    reqs = [Request(prompt=[1, 2, 3], max_new=5),
            Request(prompt=[9, 8], max_new=3),
            Request(prompt=[4], max_new=6)]
    out = engine.generate(reqs)
    assert [len(r.out) for r in out] == [5, 3, 6]
    for r in out:
        assert all(0 <= t < engine.cfg.vocab for t in r.out)


def test_generation_deterministic(engine):
    a = engine.generate([Request(prompt=[5, 6, 7], max_new=6)])[0].out
    b = engine.generate([Request(prompt=[5, 6, 7], max_new=6)])[0].out
    assert a == b


def test_submit_future_matches_generate(engine):
    """Async admission of a lone request decodes exactly like generate()."""
    want = engine.generate([Request(prompt=[2, 9, 4], max_new=5)])[0].out
    got = engine.submit([2, 9, 4], max_new=5).result(timeout=120)
    assert got == want


def test_submit_validates_synchronously(engine):
    with pytest.raises(ValueError, match="non-empty"):
        engine.submit([], max_new=3)
    with pytest.raises(ValueError, match="max_new"):
        engine.submit([1], max_new=0)
    with pytest.raises(ValueError, match="KV budget"):
        engine.submit(list(range(engine.max_seq)), max_new=1)


def test_slot_reuse_admission(engine):
    """More requests than decode slots: early finishers free slots that are
    refilled mid-round from the queue, and every answer has the right
    length. Request latencies come from the shared scheduler clock.

    submit_many enqueues atomically, so the first flush deterministically
    holds `batch` requests with the rest queued behind it — the queued ones
    MUST be admitted mid-round (the first finisher frees a slot long before
    the longest request ends the round)."""
    reused_before = engine.slots_reused
    items = engine.scheduler.submit_many(
        [([1 + i, 7, 42], 2 + i) for i in range(engine.batch + 2)])
    outs = [it.future.result(timeout=300) for it in items]
    assert [len(o) for o in outs] == [2 + i for i in range(engine.batch + 2)]
    assert engine.slots_reused > reused_before, \
        "expected mid-round admission into freed slots"
    st = engine.stats()
    assert st["sched_mid_flush_admissions"] >= engine.slots_reused
    assert st["slot_utilization"] > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch,tol", [("phi3-mini-3.8b", 0.08),
                                      ("mamba2-780m", 2e-3)])
def test_reset_decode_slot_matches_fresh_state(arch, tol):
    """Soundness of slot reuse at the model layer: after reset_decode_slot,
    a recycled slot's logits match a fresh-cache decode of the same prompt.

    For attention, the per-slot start mask hides the previous occupant and
    rope scores depend only on position DIFFERENCES, so a sequence admitted
    at position p is mathematically identical to one started at 0. The
    comparison needs a tolerance because the bf16 KV cache quantizes
    differently-rotated keys differently (~1% on these logits — which is
    also why token-exact comparisons would be flaky); a broken mask would
    diverge at the full logit scale, an order of magnitude beyond ``tol``.
    For mamba, the zeroed conv/ssm slot state IS the fresh-sequence state
    and positions never enter, so its tolerance is tight."""
    cfg = get_reduced(arch)
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    occupant = [5, 9, 2, 7]     # fills slot 1 before the reset
    prompt = [3, 8, 6]

    # fresh reference: prompt through slot 1 of a brand-new state
    st = lm.track_slot_starts(lm.init_decode_state(cfg, B, S), B)
    ref = []
    for t in prompt:
        toks = np.array([[1], [t]], np.int32)
        logits, st = lm.decode_step(cfg, params, jnp.asarray(toks), st)
        ref.append(np.asarray(logits[1]))

    # reused: decode `occupant` in slot 1 first, then reset the slot and
    # replay the same prompt mid-stream while slot 0 keeps decoding
    st = lm.track_slot_starts(lm.init_decode_state(cfg, B, S), B)
    for t in occupant:
        toks = np.array([[1], [t]], np.int32)
        _, st = lm.decode_step(cfg, params, jnp.asarray(toks), st)
    st = lm.reset_decode_slot(cfg, st, 1)
    got = []
    for t in prompt:
        toks = np.array([[1], [t]], np.int32)
        logits, st = lm.decode_step(cfg, params, jnp.asarray(toks), st)
        got.append(np.asarray(logits[1]))

    for r, g in zip(ref, got):
        np.testing.assert_allclose(g, r, atol=tol, rtol=tol)


def test_reset_decode_slot_requires_start_tracking():
    cfg = get_reduced("phi3-mini-3.8b")
    state = lm.init_decode_state(cfg, 2, 16)
    with pytest.raises(ValueError, match="track_slot_starts"):
        lm.reset_decode_slot(cfg, state, 0)


def test_data_pipeline_stateless():
    from repro.data.tokens import token_batch_fn
    bf = token_batch_fn(batch=2, seq=8, vocab=64, seed=3)
    a, b = bf(5), bf(5)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    c = bf(6)
    assert not np.array_equal(a["inputs"], c["inputs"])
    # markov structure: labels are reachable successors of inputs
    assert a["labels"].shape == (2, 8)


def test_graph_generator_properties():
    from repro.data.graphs import make_power_law_graph
    g = make_power_law_graph(500, 5000, seed=0)
    g.validate()
    assert g.nnz == 5000
    deg = np.diff(g.rowptr)
    # power-law-ish: max degree far above mean
    assert deg.max() > 5 * deg.mean()
