"""Serving engine: batched generation, determinism, slot masking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import lm
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_reduced("phi3-mini-3.8b")
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, batch=4, max_seq=64, eos_id=-1)


def test_batched_generation(engine):
    reqs = [Request(prompt=[1, 2, 3], max_new=5),
            Request(prompt=[9, 8], max_new=3),
            Request(prompt=[4], max_new=6)]
    out = engine.generate(reqs)
    assert [len(r.out) for r in out] == [5, 3, 6]
    for r in out:
        assert all(0 <= t < engine.cfg.vocab for t in r.out)


def test_generation_deterministic(engine):
    a = engine.generate([Request(prompt=[5, 6, 7], max_new=6)])[0].out
    b = engine.generate([Request(prompt=[5, 6, 7], max_new=6)])[0].out
    assert a == b


def test_data_pipeline_stateless():
    from repro.data.tokens import token_batch_fn
    bf = token_batch_fn(batch=2, seq=8, vocab=64, seed=3)
    a, b = bf(5), bf(5)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    c = bf(6)
    assert not np.array_equal(a["inputs"], c["inputs"])
    # markov structure: labels are reachable successors of inputs
    assert a["labels"].shape == (2, 8)


def test_graph_generator_properties():
    from repro.data.graphs import make_power_law_graph
    g = make_power_law_graph(500, 5000, seed=0)
    g.validate()
    assert g.nnz == 5000
    deg = np.diff(g.rowptr)
    # power-law-ish: max degree far above mean
    assert deg.max() > 5 * deg.mean()
