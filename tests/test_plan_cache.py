"""PlanCache semantics: hit/miss/build counters, LRU eviction, key hygiene."""
import numpy as np
import pytest

from repro.core.graph import gcn_normalize
from repro.core.plan_cache import (
    PartitionConfig, PlanCache, build_partition_plan, graph_content_hash,
)
from repro.core.spmm import make_accel_spmm
from repro.models.gcn import GraphOp

from conftest import make_powerlaw_csr


def _g(seed, n=120):
    return gcn_normalize(make_powerlaw_csr(n=n, seed=seed))


# ---------------------------------------------------------------------------
# content hash
# ---------------------------------------------------------------------------
def test_hash_deterministic_and_distinct():
    g1, g2 = _g(0), _g(1)
    assert graph_content_hash(g1) == graph_content_hash(_g(0))
    assert graph_content_hash(g1) != graph_content_hash(g2)


def test_hash_sensitive_to_values_not_just_structure():
    g = _g(3)
    g2 = type(g)(rowptr=g.rowptr, colidx=g.colidx,
                 values=g.values * 2.0, n_cols=g.n_cols)
    assert graph_content_hash(g) != graph_content_hash(g2)


def test_same_shape_different_colidx_distinct():
    # identical rowptr/values envelope, permuted column targets -> distinct
    a = make_powerlaw_csr(n=80, seed=10)
    b = type(a)(rowptr=a.rowptr, colidx=(a.colidx + 1) % a.n_cols,
                values=a.values, n_cols=a.n_cols)
    assert graph_content_hash(a) != graph_content_hash(b)


# ---------------------------------------------------------------------------
# hit / miss / build counters
# ---------------------------------------------------------------------------
def test_counters_hit_miss_build():
    cache = PlanCache(capacity=4)
    g = _g(0)
    cfg = PartitionConfig()
    p1 = cache.get_or_build(g, cfg)
    assert (cache.hits, cache.misses, cache.builds) == (0, 1, 1)
    p2 = cache.get_or_build(g, cfg)
    assert (cache.hits, cache.misses, cache.builds) == (1, 1, 1)
    assert p1 is p2, "hit must return the SAME staged plan object"
    st = cache.stats()
    assert st["hit_rate"] == pytest.approx(0.5)
    assert st["size"] == 1 and st["device_bytes"] > 0


def test_config_is_part_of_key():
    cache = PlanCache(capacity=8)
    g = _g(2)
    cache.get_or_build(g, PartitionConfig(mode="tpu"))
    cache.get_or_build(g, PartitionConfig(mode="paper", max_block_warps=8,
                                          max_warp_nzs=16))
    cache.get_or_build(g, PartitionConfig(mode="tpu", max_block_warps=32,
                                          max_warp_nzs=8))
    assert cache.builds == 3 and cache.hits == 0


# ---------------------------------------------------------------------------
# LRU eviction
# ---------------------------------------------------------------------------
def test_lru_eviction_order():
    cache = PlanCache(capacity=2)
    cfg = PartitionConfig()
    g0, g1, g2 = _g(0), _g(1), _g(2)
    k0 = (graph_content_hash(g0), cfg)
    cache.get_or_build(g0, cfg)
    cache.get_or_build(g1, cfg)
    cache.get_or_build(g0, cfg)          # refresh g0 -> g1 is now LRU
    cache.get_or_build(g2, cfg)          # evicts g1
    assert cache.evictions == 1 and len(cache) == 2
    assert k0 in cache
    assert (graph_content_hash(g1), cfg) not in cache
    cache.get_or_build(g1, cfg)          # rebuilt: a miss, not a hit
    assert cache.builds == 4 and cache.evictions == 2


def test_capacity_one_thrash_still_correct():
    cache = PlanCache(capacity=1)
    cfg = PartitionConfig()
    for seed in (0, 1, 0, 1):
        p = cache.get_or_build(_g(seed), cfg)
        assert p.n_rows == 120
    assert cache.builds == 4 and cache.hits == 0 and cache.evictions == 3


# ---------------------------------------------------------------------------
# integration: operators and models through the cache
# ---------------------------------------------------------------------------
def test_make_accel_spmm_shares_plan():
    cache = PlanCache()
    g = _g(5)
    op1 = make_accel_spmm(g, plan_cache=cache)
    op2 = make_accel_spmm(g, plan_cache=cache)
    assert cache.builds == 1 and cache.hits == 1
    assert op1.plan is op2.plan
    # and cached operators still compute the right thing
    import jax.numpy as jnp
    from repro.kernels.ref import csr_spmm_ref
    X = jnp.asarray(np.random.default_rng(0).normal(size=(g.n_rows, 24)),
                    dtype=jnp.float32)
    ref = np.asarray(csr_spmm_ref(g.rowptr, g.colidx, g.values, X))
    np.testing.assert_allclose(np.asarray(op2(X)), ref, atol=1e-3, rtol=1e-3)


def test_graphop_build_partitions_once_per_matrix():
    """Acceptance: serving the same graph twice partitions exactly once."""
    cache = PlanCache()
    g = _g(7)
    GraphOp.build(g, plan_cache=cache)        # builds A' and A'^T plans
    assert cache.builds == 2 and cache.misses == 2
    GraphOp.build(g, plan_cache=cache)        # all hits, zero new builds
    assert cache.builds == 2 and cache.hits == 2


def test_plan_roundtrip_without_cache_matches():
    g = _g(9)
    cfg = PartitionConfig()
    p_direct = build_partition_plan(g, cfg)
    p_cached = PlanCache().get_or_build(g, cfg)
    assert p_direct.key == p_cached.key
    assert p_direct.num_blocks == p_cached.num_blocks
    np.testing.assert_array_equal(np.asarray(p_direct.slabs["colidx"]),
                                  np.asarray(p_cached.slabs["colidx"]))


# ---------------------------------------------------------------------------
# thread safety (the serving schedulers hit the cache from flush threads)
# ---------------------------------------------------------------------------
def test_parallel_get_or_build_single_flight():
    """Satellite acceptance: N threads racing get_or_build of the SAME graph
    run the partition pipeline exactly once and share one plan object."""
    import threading
    cache = PlanCache(capacity=8)
    g, cfg = _g(21), PartitionConfig()
    plans = [None] * 8
    barrier = threading.Barrier(8)

    def worker(i):
        barrier.wait()
        plans[i] = cache.get_or_build(g, cfg)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert cache.builds == 1, "parallel misses must coalesce into one build"
    assert cache.misses == 1 and cache.hits == 7
    assert all(p is plans[0] for p in plans)


def test_parallel_distinct_graphs_build_concurrently():
    import threading
    cache = PlanCache(capacity=8)
    cfg = PartitionConfig()
    gs = [_g(30 + i) for i in range(4)]
    threads = [threading.Thread(target=cache.get_or_build, args=(g, cfg))
               for g in gs for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert cache.builds == 4 and len(cache) == 4


# ---------------------------------------------------------------------------
# disk persistence: spill evicted plans, reload on miss
# ---------------------------------------------------------------------------
def test_evicted_plan_spills_and_reloads(tmp_path):
    cache = PlanCache(capacity=1, save_dir=str(tmp_path))
    cfg = PartitionConfig()
    g0, g1 = _g(0), _g(1)
    p0 = cache.get_or_build(g0, cfg)
    cache.get_or_build(g1, cfg)          # evicts g0 -> spills to disk
    st = cache.stats()
    assert st["evictions"] == 1 and st["spills"] == 1
    assert len(list(tmp_path.glob("*.npz"))) == 1

    p0b = cache.get_or_build(g0, cfg)    # miss -> disk reload, NOT a rebuild
    st = cache.stats()
    assert st["disk_hits"] == 1
    assert st["builds"] == 2, "disk hit must not re-run the partition"
    assert p0b.key == p0.key
    assert p0b.num_blocks == p0.num_blocks
    for k in ("colidx", "values", "rowloc", "out_row"):
        np.testing.assert_array_equal(np.asarray(p0b.slabs[k]),
                                      np.asarray(p0.slabs[k]))
    np.testing.assert_array_equal(np.asarray(p0b.inv_perm),
                                  np.asarray(p0.inv_perm))
    np.testing.assert_array_equal(p0b.partition.meta, p0.partition.meta)


def test_reloaded_plan_computes_correctly(tmp_path):
    import jax.numpy as jnp
    from repro.kernels.ref import csr_spmm_ref
    cache = PlanCache(capacity=1, save_dir=str(tmp_path))
    cfg = PartitionConfig()
    g = _g(5)
    cache.get_or_build(g, cfg)
    cache.get_or_build(_g(6), cfg)       # evict + spill g
    cache.get_or_build(g, cfg)           # reload from disk
    op = make_accel_spmm(g, plan_cache=cache)
    X = jnp.asarray(np.random.default_rng(0).normal(size=(g.n_rows, 12)),
                    dtype=jnp.float32)
    ref = np.asarray(csr_spmm_ref(g.rowptr, g.colidx, g.values, X))
    np.testing.assert_allclose(np.asarray(op(X)), ref, atol=1e-3, rtol=1e-3)


def test_corrupt_spill_falls_back_to_rebuild(tmp_path):
    cache = PlanCache(capacity=1, save_dir=str(tmp_path))
    cfg = PartitionConfig()
    g0 = _g(0)
    cache.get_or_build(g0, cfg)
    cache.get_or_build(_g(1), cfg)       # evict + spill g0
    spill = next(tmp_path.glob("*.npz"))
    spill.write_bytes(b"not a real npz")
    p = cache.get_or_build(g0, cfg)      # must rebuild, not crash
    assert p.n_rows == g0.n_rows
    assert cache.stats()["disk_hits"] == 0
    assert cache.builds == 3


def test_config_tag_distinguishes_spills(tmp_path):
    cache = PlanCache(capacity=1, save_dir=str(tmp_path))
    g = _g(3)
    cache.get_or_build(g, PartitionConfig(mode="tpu"))
    cache.get_or_build(g, PartitionConfig(mode="tpu", max_block_warps=32))
    # second build evicted+spilled the first; same graph hash, distinct tag
    names = {p.name for p in tmp_path.glob("*.npz")}
    assert len(names) == 1
    cache.get_or_build(_g(4), PartitionConfig(mode="tpu", max_block_warps=32))
    assert len(list(tmp_path.glob("*.npz"))) == 2


# ---------------------------------------------------------------------------
# stats atomicity
# ---------------------------------------------------------------------------
def test_stats_snapshot_atomic_under_hammering_thread():
    """Regression: ``stats()`` is one consistent snapshot taken under the
    cache lock. ``lookups`` is bumped in the SAME lock hold as ``hits`` /
    ``misses``, so any torn read (counters sampled at two different moments
    while a flush thread mutates them) would show up as
    ``hits + misses != lookups`` or an out-of-range derived value."""
    import threading

    cfg = PartitionConfig()
    cache = PlanCache(capacity=4)
    graphs = [_g(200 + i, n=60) for i in range(8)]  # > capacity: evictions too
    stop = threading.Event()
    errors = []

    def hammer(tid):
        k = 0
        while not stop.is_set():
            cache.get_or_build(graphs[(tid + k) % len(graphs)], cfg)
            k += 1

    def sampler():
        while not stop.is_set():
            s = cache.stats()
            try:
                assert s["hits"] + s["misses"] == s["lookups"], s
                assert 0.0 <= s["hit_rate"] <= 1.0
                assert s["size"] <= s["capacity"]
                assert s["builds"] + s["disk_hits"] <= s["misses"]
            except AssertionError as e:
                errors.append(e)
                return

    threads = [threading.Thread(target=hammer, args=(t,)) for t in range(3)]
    threads += [threading.Thread(target=sampler) for _ in range(2)]
    for t in threads:
        t.start()
    import time
    time.sleep(1.5)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, f"torn stats snapshot observed: {errors[0]}"
    # quiesced: the invariant holds exactly
    s = cache.stats()
    assert s["hits"] + s["misses"] == s["lookups"]
