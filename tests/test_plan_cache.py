"""PlanCache semantics: hit/miss/build counters, LRU eviction, key hygiene."""
import numpy as np
import pytest

from repro.core.graph import gcn_normalize
from repro.core.plan_cache import (
    PartitionConfig, PlanCache, build_partition_plan, graph_content_hash,
)
from repro.core.spmm import make_accel_spmm
from repro.models.gcn import GraphOp

from conftest import make_powerlaw_csr


def _g(seed, n=120):
    return gcn_normalize(make_powerlaw_csr(n=n, seed=seed))


# ---------------------------------------------------------------------------
# content hash
# ---------------------------------------------------------------------------
def test_hash_deterministic_and_distinct():
    g1, g2 = _g(0), _g(1)
    assert graph_content_hash(g1) == graph_content_hash(_g(0))
    assert graph_content_hash(g1) != graph_content_hash(g2)


def test_hash_sensitive_to_values_not_just_structure():
    g = _g(3)
    g2 = type(g)(rowptr=g.rowptr, colidx=g.colidx,
                 values=g.values * 2.0, n_cols=g.n_cols)
    assert graph_content_hash(g) != graph_content_hash(g2)


def test_same_shape_different_colidx_distinct():
    # identical rowptr/values envelope, permuted column targets -> distinct
    a = make_powerlaw_csr(n=80, seed=10)
    b = type(a)(rowptr=a.rowptr, colidx=(a.colidx + 1) % a.n_cols,
                values=a.values, n_cols=a.n_cols)
    assert graph_content_hash(a) != graph_content_hash(b)


# ---------------------------------------------------------------------------
# hit / miss / build counters
# ---------------------------------------------------------------------------
def test_counters_hit_miss_build():
    cache = PlanCache(capacity=4)
    g = _g(0)
    cfg = PartitionConfig()
    p1 = cache.get_or_build(g, cfg)
    assert (cache.hits, cache.misses, cache.builds) == (0, 1, 1)
    p2 = cache.get_or_build(g, cfg)
    assert (cache.hits, cache.misses, cache.builds) == (1, 1, 1)
    assert p1 is p2, "hit must return the SAME staged plan object"
    st = cache.stats()
    assert st["hit_rate"] == pytest.approx(0.5)
    assert st["size"] == 1 and st["device_bytes"] > 0


def test_config_is_part_of_key():
    cache = PlanCache(capacity=8)
    g = _g(2)
    cache.get_or_build(g, PartitionConfig(mode="tpu"))
    cache.get_or_build(g, PartitionConfig(mode="paper", max_block_warps=8,
                                          max_warp_nzs=16))
    cache.get_or_build(g, PartitionConfig(mode="tpu", max_block_warps=32,
                                          max_warp_nzs=8))
    assert cache.builds == 3 and cache.hits == 0


# ---------------------------------------------------------------------------
# LRU eviction
# ---------------------------------------------------------------------------
def test_lru_eviction_order():
    cache = PlanCache(capacity=2)
    cfg = PartitionConfig()
    g0, g1, g2 = _g(0), _g(1), _g(2)
    k0 = (graph_content_hash(g0), cfg)
    cache.get_or_build(g0, cfg)
    cache.get_or_build(g1, cfg)
    cache.get_or_build(g0, cfg)          # refresh g0 -> g1 is now LRU
    cache.get_or_build(g2, cfg)          # evicts g1
    assert cache.evictions == 1 and len(cache) == 2
    assert k0 in cache
    assert (graph_content_hash(g1), cfg) not in cache
    cache.get_or_build(g1, cfg)          # rebuilt: a miss, not a hit
    assert cache.builds == 4 and cache.evictions == 2


def test_capacity_one_thrash_still_correct():
    cache = PlanCache(capacity=1)
    cfg = PartitionConfig()
    for seed in (0, 1, 0, 1):
        p = cache.get_or_build(_g(seed), cfg)
        assert p.n_rows == 120
    assert cache.builds == 4 and cache.hits == 0 and cache.evictions == 3


# ---------------------------------------------------------------------------
# integration: operators and models through the cache
# ---------------------------------------------------------------------------
def test_make_accel_spmm_shares_plan():
    cache = PlanCache()
    g = _g(5)
    op1 = make_accel_spmm(g, plan_cache=cache)
    op2 = make_accel_spmm(g, plan_cache=cache)
    assert cache.builds == 1 and cache.hits == 1
    assert op1.plan is op2.plan
    # and cached operators still compute the right thing
    import jax.numpy as jnp
    from repro.kernels.ref import csr_spmm_ref
    X = jnp.asarray(np.random.default_rng(0).normal(size=(g.n_rows, 24)),
                    dtype=jnp.float32)
    ref = np.asarray(csr_spmm_ref(g.rowptr, g.colidx, g.values, X))
    np.testing.assert_allclose(np.asarray(op2(X)), ref, atol=1e-3, rtol=1e-3)


def test_graphop_build_partitions_once_per_matrix():
    """Acceptance: serving the same graph twice partitions exactly once."""
    cache = PlanCache()
    g = _g(7)
    GraphOp.build(g, plan_cache=cache)        # builds A' and A'^T plans
    assert cache.builds == 2 and cache.misses == 2
    GraphOp.build(g, plan_cache=cache)        # all hits, zero new builds
    assert cache.builds == 2 and cache.hits == 2


def test_plan_roundtrip_without_cache_matches():
    g = _g(9)
    cfg = PartitionConfig()
    p_direct = build_partition_plan(g, cfg)
    p_cached = PlanCache().get_or_build(g, cfg)
    assert p_direct.key == p_cached.key
    assert p_direct.num_blocks == p_cached.num_blocks
    np.testing.assert_array_equal(np.asarray(p_direct.slabs["colidx"]),
                                  np.asarray(p_cached.slabs["colidx"]))
