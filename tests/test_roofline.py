"""Roofline machinery: HLO collective parser + term arithmetic."""
import pytest

from repro.analysis.roofline import (collective_bytes, model_flops_estimate,
                                     roofline_terms)

HLO = """
ENTRY %main {
  %ag = f32[3072,192]{1,0} all-gather(%p0), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %ar = bf16[1024,512]{1,0} all-reduce(%x), channel_id=2, replica_groups=[16,16]<=[256]
  %rs = f32[64,64]{1,0} reduce-scatter(%y), channel_id=3, replica_groups=[16,16]<=[256], dimensions={0}
  %cp = f32[128]{0} collective-permute(%z), channel_id=4
  %a2a = bf16[32,32]{1,0} all-to-all(%w), channel_id=5
  %ard = f32[8,8]{1,0} all-reduce-done(%ar2)
  %not-a-collective = f32[9999]{0} add(%a, %b)
}
"""


def test_collective_parser_kinds_and_sizes():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 3072 * 192 * 4          # 1x result
    assert out["all-reduce"] == 2 * 1024 * 512 * 2      # 2x ring, bf16
    assert out["reduce-scatter"] == 64 * 64 * 4 * 16    # result x group
    assert out["collective-permute"] == 128 * 4
    assert out["all-to-all"] == 32 * 32 * 2
    # -done halves are not double counted
    assert sum(out.values()) < 10_000_000


def test_done_ops_skipped():
    txt = "%x = f32[100]{0} all-reduce-start(%a)\n%y = f32[100]{0} all-reduce-done(%x)"
    out = collective_bytes(txt)
    assert out["all-reduce"] == 2 * 100 * 4  # start counted once


def test_roofline_terms_and_bottleneck():
    cost = {"flops": 197e12, "bytes accessed": 819e9 / 2}
    rl = roofline_terms(cost, HLO, chips=256, model_flops=197e12 * 256 * 0.5)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(0.5)
    assert rl.bottleneck == "compute"
    assert rl.useful_ratio == pytest.approx(0.5)


def test_model_flops():
    assert model_flops_estimate(1e9, 1e6, "train") == 6e15
    assert model_flops_estimate(1e9, 1e6, "infer") == 2e15
