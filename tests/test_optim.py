"""AdamW vs a straightforward numpy reference; schedule and clipping."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               cosine_schedule)


def test_adamw_matches_reference():
    p = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(5,)),
                          dtype=jnp.float32)}
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(5,)),
                          dtype=jnp.float32)}
    st = adamw_init(p)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
    new_p, st2, _ = adamw_update(g, st, p, lr=lr, b1=b1, b2=b2, eps=eps,
                                 weight_decay=wd, max_grad_norm=None)
    # numpy reference
    m = (1 - b1) * np.asarray(g["w"])
    v = (1 - b2) * np.asarray(g["w"]) ** 2
    mh, vh = m / (1 - b1), v / (1 - b2)
    ref = np.asarray(p["w"]) - lr * (mh / (np.sqrt(vh) + eps)
                                     + wd * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, atol=1e-6)
    assert int(st2.step) == 1


def test_bf16_params_fp32_master():
    p = {"w": jnp.full((3,), 0.1, jnp.bfloat16)}
    st = adamw_init(p)
    assert st.master["w"].dtype == jnp.float32
    g = {"w": jnp.full((3,), 1.0, jnp.bfloat16)}
    new_p, st2, _ = adamw_update(g, st, p, lr=1e-3)
    assert new_p["w"].dtype == jnp.bfloat16
    # master moved even if bf16 quantization hides tiny deltas
    assert not np.allclose(np.asarray(st2.master["w"]), np.asarray(st.master["w"]))


def test_clipping():
    g = {"a": jnp.full((4,), 3.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(6.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), 0.5, rtol=1e-5)


def test_cosine_schedule_shape():
    import numpy as np
    s = [float(cosine_schedule(jnp.asarray(t), peak_lr=1.0, warmup=10,
                               total=100)) for t in range(100)]
    assert s[0] == 0.0 and s[10] == pytest.approx(1.0, abs=1e-2)
    assert s[99] < 0.2 and min(s[10:]) >= 0.1 * 1.0 - 1e-6  # floor
