"""Mamba-2 SSD: chunked scan vs naive recurrence; decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.ssm import (MambaCache, init_mamba2, mamba2_decode,
                              mamba2_forward, ssd_chunked)


def naive_ssd(x, dt, A, B, C):
    b, T, H, P = x.shape
    N = B.shape[-1]
    h = np.zeros((b, H, N, P))
    ys = []
    for t in range(T):
        dec = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])
        h = dec[:, :, None, None] * h + np.einsum(
            "bn,bh,bhp->bhnp", np.asarray(B[:, t]), np.asarray(dt[:, t]),
            np.asarray(x[:, t]))
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(C[:, t]), h))
    return np.stack(ys, 1), h


@settings(max_examples=10, deadline=None)
@given(T=st.sampled_from([8, 16, 32, 64]), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 100))
def test_ssd_vs_recurrence(T, chunk, seed):
    b, H, P, N = 2, 3, 4, 5
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, T, N))
    C = jax.random.normal(ks[4], (b, T, N))
    ref_y, ref_h = naive_ssd(x, dt, A, B, C)
    y, h = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), ref_y, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), ref_h, atol=1e-4, rtol=1e-4)


def test_initial_state_threading():
    b, T, H, P, N = 1, 16, 2, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, T, N))
    C = jax.random.normal(ks[4], (b, T, N))
    y_full, h_full = ssd_chunked(x, dt, A, B, C, chunk=8)
    # split in two halves, threading the state
    y1, h1 = ssd_chunked(x[:, :8], dt[:, :8], A, B[:, :8], C[:, :8], chunk=8)
    y2, h2 = ssd_chunked(x[:, 8:], dt[:, 8:], A, B[:, 8:], C[:, 8:], h0=h1, chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-5)


def test_forward_decode_consistency_fp32():
    D, di, hd, stt = 16, 32, 8, 5
    p = init_mamba2(jax.random.PRNGKey(7), D, di, hd, stt, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 16, D))
    y_full, cache_f = mamba2_forward(p, x, head_dim=hd, state=stt, chunk=8,
                                     return_state=True)
    cache = MambaCache.create(2, 4, di + 2 * stt, di // hd, stt, hd,
                              dtype=jnp.float32)
    ys = []
    for t in range(16):
        yt, cache = mamba2_decode(p, x[:, t:t + 1], cache, head_dim=hd, state=stt)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), atol=1e-5)
    # prefill-returned state == decode-accumulated state
    np.testing.assert_allclose(np.asarray(cache.ssm), np.asarray(cache_f.ssm),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(cache.conv), np.asarray(cache_f.conv),
                               atol=1e-6)
