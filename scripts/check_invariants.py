#!/usr/bin/env python
"""Run the project invariant analyzer (src/repro/statics) over the tree.

Exit 0 when no unsuppressed findings; exit 1 otherwise.  CI runs this as
the `invariants` job.

Usage:
    python scripts/check_invariants.py                  # default paths
    python scripts/check_invariants.py src/repro/serve  # explicit paths
    python scripts/check_invariants.py --rules lock     # one family
    python scripts/check_invariants.py --list-rules
    python scripts/check_invariants.py --json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.statics import ALL_RULES, RULE_FAMILIES, analyze_paths  # noqa: E402

DEFAULT_PATHS = ["src/repro", "benchmarks", "scripts"]


def _resolve_rules(spec: str | None) -> set[str] | None:
    if spec is None:
        return None
    out: set[str] = set()
    for token in spec.split(","):
        token = token.strip()
        if not token:
            continue
        if token in RULE_FAMILIES:
            out.update(RULE_FAMILIES[token])
        elif token in ALL_RULES:
            out.add(token)
        else:
            sys.exit(f"unknown rule or family: {token!r} (see --list-rules)")
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to check (default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule names or families "
                         f"({', '.join(RULE_FAMILIES)})")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    args = ap.parse_args(argv)

    if args.list_rules:
        for family, rules in RULE_FAMILIES.items():
            print(f"{family}:")
            for r in rules:
                print(f"  {r}")
        return 0

    paths = args.paths or [str(REPO_ROOT / p) for p in DEFAULT_PATHS]
    findings, n_files = analyze_paths(paths, rules=_resolve_rules(args.rules))

    if args.as_json:
        print(json.dumps(
            [{"rule": f.rule, "path": f.path, "line": f.line, "message": f.message}
             for f in findings],
            indent=2,
        ))
    else:
        for f in findings:
            print(f.format())
        label = "finding" if len(findings) == 1 else "findings"
        print(f"checked {n_files} files: {len(findings)} {label}", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
