#!/usr/bin/env python
"""Benchmark acceptance + regression gate for nightly CI.

Reads a fresh ``benchmarks/results/serve_stats.json`` (produced by
``python -m benchmarks.run --only serve,routing,fleet[,repair,multihost]``)
and

* asserts the ABSOLUTE acceptance properties of the serving stack
  (cross-caller coalescing, fleet-vs-single coalescing, block-shard
  balance, zipf hot-plan replication, incremental plan repair >= 3x a
  full rebuild at 0.1% churn, online partition autotuner promoting a
  non-default config whose steady state is >= 1.0x the default), and
* compares throughput rows against a COMMITTED baseline
  (``benchmarks/baselines/serve_stats.baseline.json``), failing on a
  >20% drop so perf regressions surface as red nightlies instead of
  silently compounding.

Parallel-hardware gates (fleet occupancy >= 0.75, replicated >= 1.3x
replication-disabled requests/s) only make sense where device launches
can actually overlap; on a single-core container XLA serializes every
dispatch, so those two gates are enforced when ``os.cpu_count() >= 4``
(the nightly runners) and reported informationally below that. The
structural replication gates — hot plan promoted to >= 2 replicas, its
dispatches spread across devices, and replicated occupancy >= 3x the
single-owner run — hold on any machine and are always enforced.

Exit code 0 = all gates pass; 1 = failure (messages on stderr).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(REPO, "benchmarks", "results", "serve_stats.json")
BASELINE = os.path.join(REPO, "benchmarks", "baselines",
                        "serve_stats.baseline.json")

# fresh-run throughput may drop this much vs the committed baseline
# before the gate trips (nightly runners are shared: some noise is life)
MAX_DROP = 0.20

# (json path, human name) of the throughput rows under regression watch
THROUGHPUT_ROWS = [
    (("scheduler", "requests_per_s"), "scheduler requests/s"),
    (("fleet", "single", "requests_per_s"), "single-device requests/s"),
    (("fleet", "fleet", "requests_per_s"), "fleet requests/s"),
    (("fleet", "zipf", "replicated", "requests_per_s"),
     "zipf replicated requests/s"),
]


def _get(d: Dict, path) -> object:
    for k in path:
        d = d[k]
    return d


class Gate:
    def __init__(self) -> None:
        self.failures: List[str] = []

    def check(self, ok: bool, msg: str) -> None:
        print(("PASS  " if ok else "FAIL  ") + msg)
        if not ok:
            self.failures.append(msg)

    def info(self, msg: str) -> None:
        print("INFO  " + msg)


def check_serving(g: Gate, s: Dict, *, parallel: bool) -> None:
    gpd = s["scheduler"]["graphs_per_dispatch"]
    g.check(gpd > 1.0, f"cross-caller coalescing: graphs_per_dispatch="
                       f"{gpd:.2f} > 1.0")

    fl = s["fleet"]
    gpr = fl["fleet"]["fleet_graphs_per_round"]
    single_gpd = fl["single"]["graphs_per_dispatch"]
    g.check(gpr >= single_gpd,
            f"fleet coalescing: graphs_per_round={gpr:.2f} >= "
            f"single graphs_per_dispatch={single_gpd:.2f}")

    nbs = fl["giant"]["block_sharded_dispatches"]
    g.check(nbs >= 1, f"giant graph block-sharded: dispatches={nbs} >= 1")
    bal = fl["giant"]["block_balance"]
    g.check(1.0 <= bal <= 1.10,
            f"block placement balance: {bal:.3f} within [1.0, 1.10]")

    # ---- zipf hot-plan replication ------------------------------------
    z = fl["zipf"]
    rep, dis = z["replicated"], z["disabled"]
    g.check(rep["promotions"] >= 1,
            f"hot-plan promotion fired: promotions={rep['promotions']}")
    g.check(rep["replica_copies"] >= 1,
            f"replica copies staged: {rep['replica_copies']}")
    disp = [d for d in rep["fleet_device_dispatches"] if d > 0]
    g.check(len(disp) >= 2,
            f"replicated dispatches spread over {len(disp)} devices (>= 2)")
    occ_r, occ_d = rep["fleet_occupancy"], dis["fleet_occupancy"]
    g.check(occ_r >= 3.0 * occ_d,
            f"replication lifts occupancy: {occ_r:.2f} >= 3x "
            f"single-owner {occ_d:.2f}")
    if parallel:
        g.check(occ_r >= 0.75,
                f"fleet occupancy under zipf mix: {occ_r:.2f} >= 0.75")
        g.check(z["speedup"] >= 1.3,
                f"replicated vs disabled speedup: {z['speedup']:.2f} >= 1.3x")
    else:
        g.info(f"single-core host (cpu_count={os.cpu_count()}): occupancy="
               f"{occ_r:.2f} speedup={z['speedup']:.2f} reported only — "
               f"launches cannot overlap without cores")


def check_repair(g: Gate, s: Dict) -> None:
    r = s.get("repair")
    if r is None:
        g.check(False, "repair section present in results "
                       "(run benchmarks with --only repair)")
        return
    sp = r["repair_speedup"]
    g.check(sp >= 3.0,
            f"incremental plan repair at 0.1% nnz churn: {sp:.2f}x >= 3x "
            f"over full rebuild")
    for key, frac in sorted(
            (k, k.split("_", 1)[1]) for k in r if k.startswith("frac_")):
        fr = r[key]
        g.check(fr["speedup"] >= 1.0 if fr["repaired"] else True,
                f"repair at {frac} churn never slower than rebuild: "
                f"{fr['speedup']:.2f}x (repaired={fr['repaired']})")


def check_multihost(g: Gate, s: Dict) -> None:
    mh = s.get("multihost")
    if mh is None:
        g.check(False, "multihost section present in results "
                       "(run benchmarks with --only multihost)")
        return
    hp = mh["host_placements"]
    g.check(len(hp) == 2 and all(c >= 1 for c in hp),
            f"directory spread plans across both hosts: {hp}")
    g.check(mh["forwarded"] >= 1,
            f"cross-host forwarding happened: forwarded={mh['forwarded']}")
    fo = sum(r["failovers"] for r in mh["per_rank"])
    g.check(fo == 0, f"no unexpected peer failovers: {fo}")
    bc = mh["block_counts"]
    g.check(bool(bc) and max(bc) - min(bc) <= 1,
            f"global block shard balanced: {bc}")


def check_tuning(g: Gate, s: Dict, *, parallel: bool) -> None:
    t = s.get("tuning")
    if t is None:
        g.check(False, "tuning section present in results "
                       "(run benchmarks with --only tune)")
        return
    on = t["online"]
    g.check(on["promotions"] >= 1,
            f"online tuner promoted a config: promotions={on['promotions']}")
    g.check(not on["tuned_config_default"],
            f"promoted config is non-default: label={on['tuned_label']}")
    sp = on["tuned_speedup"]
    g.check(sp >= 1.0,
            f"tuned steady-state beats default dispatch: {sp:.2f}x >= 1.0x")
    off = t["offline"]
    g.check(off["best_speedup"] >= 1.0,
            f"offline search found headroom: best={off['best_label']} "
            f"{off['best_speedup']:.2f}x >= 1.0x")
    ratio = t["shadow"]["p99_ratio"]
    if parallel:
        g.check(ratio <= 1.05,
                f"shadowing off the critical path: p99 ratio "
                f"{ratio:.3f} <= 1.05 vs tuner disabled")
    else:
        g.info(f"single-core host (cpu_count={os.cpu_count()}): shadow p99 "
               f"ratio={ratio:.3f} reported only — the shadow worker "
               f"shares the lone core with live dispatches")


def check_sampling(g: Gate, s: Dict) -> None:
    sm = s.get("sampling")
    if sm is None:
        g.check(False, "sampling section present in results "
                       "(run benchmarks with --only sample)")
        return
    hr = sm["zipf_stream"]["hit_rate"]
    g.check(hr >= 0.5,
            f"zipf seed-stream frontier hit rate: {hr:.3f} >= 0.5")
    for backend, ex in sorted(sm["exactness"].items()):
        g.check(ex["exact"],
                f"full-fanout sampled inference bit-exact on {backend}: "
                f"max_abs_diff={ex['max_abs_diff']:.3g}")
    part = sm.get("partitioned")
    if part is None:
        g.check(False, "partitioned-store run present in sampling section")
        return
    g.check(part["parity"],
            "partitioned sampling matches the monolithic store "
            f"({part['processes']} processes, "
            f"{len(part['per_rank'])} ranks reporting)")
    g.check(part["remote_edges"] >= 1,
            f"cross-partition hops actually crossed the data plane: "
            f"remote_edges={part['remote_edges']}")
    g.check(part["failovers"] == 0,
            f"no frontier-exchange failovers: {part['failovers']}")


def check_regression(g: Gate, s: Dict, baseline_path: str) -> None:
    if not os.path.exists(baseline_path):
        g.check(False, f"baseline missing: {baseline_path}")
        return
    with open(baseline_path) as f:
        base = json.load(f)
    for path, name in THROUGHPUT_ROWS:
        try:
            b = float(_get(base, path))
        except (KeyError, TypeError):
            g.info(f"{name}: not in baseline, skipped")
            continue
        try:
            v = float(_get(s, path))
        except (KeyError, TypeError):
            g.check(False, f"{name}: missing from fresh results")
            continue
        floor = b * (1.0 - MAX_DROP)
        g.check(v >= floor,
                f"{name}: {v:.1f} >= {floor:.1f} "
                f"(baseline {b:.1f} - {MAX_DROP:.0%})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--results", default=RESULTS,
                    help="fresh serve_stats.json to gate")
    ap.add_argument("--baseline", default=BASELINE,
                    help="committed baseline to diff against")
    ap.add_argument("--require-multihost", action="store_true",
                    help="also gate the multihost section (nightly runs "
                         "it; quick local runs may not)")
    ap.add_argument("--require-repair", action="store_true",
                    help="also gate the plan-repair section (produced by "
                         "--only repair; nightly runs it)")
    ap.add_argument("--require-tuning", action="store_true",
                    help="also gate the partition-autotuner section "
                         "(produced by --only tune; nightly runs it)")
    ap.add_argument("--require-sampling", action="store_true",
                    help="also gate the neighbor-sampling section "
                         "(produced by --only sample; nightly runs it)")
    ap.add_argument("--parallel", choices=["auto", "on", "off"],
                    default="auto",
                    help="enforce the parallel-hardware gates (occupancy "
                         ">= 0.75, speedup >= 1.3); auto = cpu_count >= 4")
    args = ap.parse_args(argv)

    with open(args.results) as f:
        s = json.load(f)
    parallel = (args.parallel == "on"
                or (args.parallel == "auto"
                    and (os.cpu_count() or 1) >= 4))

    g = Gate()
    check_serving(g, s, parallel=parallel)
    if args.require_multihost:
        check_multihost(g, s)
    elif "multihost" in s:
        check_multihost(g, s)
    else:
        g.info("multihost section absent, skipped "
               "(pass --require-multihost to make that a failure)")
    if args.require_repair or "repair" in s:
        check_repair(g, s)
    else:
        g.info("repair section absent, skipped "
               "(pass --require-repair to make that a failure)")
    if args.require_tuning or "tuning" in s:
        check_tuning(g, s, parallel=parallel)
    else:
        g.info("tuning section absent, skipped "
               "(pass --require-tuning to make that a failure)")
    if args.require_sampling or "sampling" in s:
        check_sampling(g, s)
    else:
        g.info("sampling section absent, skipped "
               "(pass --require-sampling to make that a failure)")
    check_regression(g, s, args.baseline)

    if g.failures:
        print(f"\n{len(g.failures)} gate(s) failed:", file=sys.stderr)
        for msg in g.failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("\nall gates passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
