"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
benchmarks/results/dryrun.json."""
import json
import sys

HW_NOTE = "TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI; 16 GB HBM/chip"


def human(n):
    if n is None:
        return "-"
    for unit in ("", "K", "M", "G", "T", "P", "E"):
        if abs(n) < 1000:
            return f"{n:.3g}{unit}"
        n /= 1000
    return f"{n:.3g}Z"


def main(path="benchmarks/results/dryrun.json"):
    recs = json.load(open(path))
    recs.sort(key=lambda r: (r["arch"], r["shape"]))

    print("### §Dry-run table (per-device memory analysis; both meshes)\n")
    print(f"_{HW_NOTE}_\n")
    print("| arch | shape | mesh | compile s | args GB/dev | temp GB/dev | "
          "rolled coll B/dev | fits 16GB? |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        cell = f"{r['arch']} | {r['shape']}"
        if "skipped" in r:
            print(f"| {cell} | — | — | — | — | — | SKIP: {r['skipped']} |")
            continue
        if "error" in r:
            print(f"| {cell} | — | — | — | — | — | ERROR |")
            continue
        for mesh in ("pod16x16", "multipod2x16x16"):
            m = r.get(mesh)
            if not m:
                continue
            tot = (m["argument_bytes_per_dev"] + m["temp_bytes_per_dev"]) / 1e9
            fits = "yes" if tot < 16 else f"no ({tot:.0f}GB)"
            print(f"| {cell} | {mesh} | {m['compile_s']:.1f} | "
                  f"{m['argument_bytes_per_dev']/1e9:.2f} | "
                  f"{m['temp_bytes_per_dev']/1e9:.2f} | "
                  f"{human(m['rolled_cost']['coll'])} | {fits} |")

    print("\n### §Roofline table (single-pod 16x16; probe-extrapolated)\n")
    print("| arch | shape | compute s | memory s | collective s | bottleneck | "
          "MODEL_FLOPS | useful ratio |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if "skipped" in r or "error" in r or "roofline" not in r:
            continue
        rl = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4g} | "
              f"{rl['memory_s']:.4g} | {rl['collective_s']:.4g} | "
              f"**{rl['bottleneck']}** | {human(rl['model_flops'])} | "
              f"{rl['useful_ratio']:.3f} |")


if __name__ == "__main__":
    main(*sys.argv[1:])
