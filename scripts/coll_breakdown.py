"""Diagnose: where do collective bytes come from? Groups HLO collective ops
by (kind, dtype, source op_name prefix) for one probe cell.

    PYTHONPATH=src python scripts/coll_breakdown.py --arch dbrx-132b \
        --shape train_4k [--variant bf16_attn]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import re
import sys
from collections import defaultdict

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

LINE_RE = re.compile(
    r"=\s+(\(?[a-z0-9#,\[\]{}() ]+?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
NAME_RE = re.compile(r'op_name="([^"]*)"')
BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--layers", type=int, default=2)
    args = ap.parse_args()

    from hillclimb import VARIANTS, apply_flags  # same dir
    apply_flags(VARIANTS[args.variant])

    from repro.configs import SHAPES_BY_NAME, get_config
    from repro.launch.dryrun import lower_and_compile
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(args.arch).replace(n_layers=args.layers)
    shape = SHAPES_BY_NAME[args.shape]
    mesh = make_production_mesh(multi_pod=False)
    T = shape.seq_len
    chunks = {"q_chunk": min(4096, T), "kv_chunk": min(4096, T),
              "loss_chunk": min(4096, T), "ssd_chunk": 128}
    _, compiled, dt = lower_and_compile(cfg, shape, mesh, chunks=chunks,
                                        unroll=True)
    txt = compiled.as_text()
    agg = defaultdict(int)
    for line in txt.splitlines():
        m = LINE_RE.search(line)
        if not m or m.group(3) == "-done":
            continue
        kind = m.group(2)
        total = 0
        dtype = "?"
        for sm in SHAPE_RE.finditer(m.group(1)):
            dtype = sm.group(1)
            n = 1
            for d in (sm.group(2).split(",") if sm.group(2) else []):
                n *= int(d)
            total += n * BYTES.get(dtype, 0)
        nm = NAME_RE.search(line)
        src = "?"
        if nm:
            parts = nm.group(1).split("/")
            keep = [p for p in parts if not p.startswith(("jit", "jvp", "transpose",
                                                          "closed_call",
                                                          "checkpoint",
                                                          "rematted"))]
            src = "/".join(keep[:3]) or parts[-1]
        agg[(kind, dtype, src)] += total
    rows = sorted(agg.items(), key=lambda kv: -kv[1])[:25]
    print(f"# {args.arch} x {args.shape} x {args.variant} "
          f"({args.layers} layers, unrolled) compile={dt:.0f}s")
    for (kind, dtype, src), b in rows:
        print(f"{b/1e9:10.3f} GB  {kind:18s} {dtype:5s} {src}")


if __name__ == "__main__":
    main()
