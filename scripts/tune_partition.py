#!/usr/bin/env python
"""Offline one-shot partition tuning for a saved graph.

Thin CLI over :func:`repro.tuning.tune_offline`: builds the incumbent
partition plan plus every candidate config (warp_nzs tables, slab
capacity, row-packing cap — see ``repro/tuning/search.py``), times one
batched SpMM dispatch per candidate (1 warmup + best-of-N), and prints
the ranking as JSON. The best candidate's config is exactly what you'd
pass as ``PartitionConfig(**...)`` when registering the graph — or let
the online tuner (``GraphServeEngine(tuner=PlanTuner())``) find it from
live traffic with shadow measurements.

Graph input: an .npz with ``rowptr``/``colidx``/``values`` (and optional
``n_cols``), or ``--synthetic N,M,SEED`` for a power-law demo graph.

    PYTHONPATH=src python scripts/tune_partition.py --graph g.npz
    PYTHONPATH=src python scripts/tune_partition.py --synthetic 20000,100000,0
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def load_graph(args):
    import numpy as np

    from repro.core.graph import CSRGraph

    if args.graph:
        with np.load(args.graph) as z:
            rowptr = z["rowptr"]
            colidx = z["colidx"]
            values = (z["values"] if "values" in z
                      else np.ones(len(colidx), dtype=np.float32))
            n_cols = (int(z["n_cols"]) if "n_cols" in z
                      else int(colidx.max()) + 1 if len(colidx) else 0)
        return CSRGraph(rowptr=rowptr, colidx=colidx,
                        values=np.asarray(values, np.float32),
                        n_cols=n_cols)
    n, m, seed = (int(v) for v in args.synthetic.split(","))
    from repro.data.graphs import make_power_law_graph
    return make_power_law_graph(n, m, seed=seed)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--graph", help=".npz with rowptr/colidx[/values/n_cols]")
    src.add_argument("--synthetic", metavar="N,M,SEED",
                     help="power-law graph: nodes,edges,seed")
    ap.add_argument("--feat-dim", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed runs per candidate (best is kept)")
    ap.add_argument("--backend", default="blocked",
                    help="measurement backend (auto|pallas|windowed|hbm|"
                         "blocked); per-candidate overrides still apply")
    ap.add_argument("--mode", default="tpu", choices=["tpu", "paper"])
    ap.add_argument("--max-block-warps", type=int, default=64)
    ap.add_argument("--max-warp-nzs", type=int, default=4)
    ap.add_argument("--out", help="also write the JSON report here")
    args = ap.parse_args(argv)

    from repro.core.plan_cache import PartitionConfig
    from repro.tuning import tune_offline

    g = load_graph(args)
    base = PartitionConfig(mode=args.mode,
                           max_block_warps=args.max_block_warps,
                           max_warp_nzs=args.max_warp_nzs)
    report = tune_offline(g, base, feat_dim=args.feat_dim,
                          repeats=args.repeats, backend=args.backend)
    report["graph"] = {"n_rows": g.n_rows, "n_cols": g.n_cols, "nnz": g.nnz}
    text = json.dumps(report, indent=2, default=str)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    best = report["best"]
    if best is not None:
        print(f"\nbest: {best['label']} "
              f"({best['speedup_vs_base']:.2f}x vs base)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
