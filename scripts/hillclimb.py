"""Perf hillclimbing harness for the LM ROOFLINE variants only.

Measures one (cell x variant) of the legacy language-model program and
appends the probe-extrapolated roofline vector to
``benchmarks/results/hillclimb.json``:

    PYTHONPATH=src python scripts/hillclimb.py --arch dbrx-132b \
        --shape train_4k --variant bf16_attn

``VARIANTS`` below are named LM flag bundles (attention precision, MoE
dispatch, sharding levers) — they do NOT cover the graph-serving stack.
Partition/SpMM tuning moved to its own tools: ``scripts/tune_partition.py``
for offline one-shot tuning of a saved graph, and
:class:`repro.tuning.PlanTuner` for the online shadow-measured autotuner
inside the serving engines (see ``src/repro/tuning/``).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402, F401 — imported early so backend init happens once


VARIANTS = {
    # paper-faithful production program as lowered for the baseline table
    "baseline": {},
    # attention/KV math in bf16 with fp32 MXU accumulation (no fp32 copies)
    "bf16_attn": {"bf16_attn": True},
    # gradient accumulation over 4 microbatches (memory lever)
    "microbatch4": {"microbatch_div": 4},
    # drop the explicit q/k/v head-sharding constraint (XLA chooses)
    "headshard_off": {"headshard_off": True},
    # ZeRO-1 for expert weights: replicate MoE params over "data" in compute,
    # shard only optimizer state (per-layer gathers -> one per-step pair)
    "zero1_moe": {"zero1_moe": True},
    # GShard-style grouped MoE dispatch: per-data-shard capacity + local
    # scatter; kills the replicated-scatter u32 all-gathers (61% of dbrx
    # collective bytes in the baseline breakdown)
    "moe_grouped": {"dispatch_groups": 16},
    # combined levers
    "bf16_attn+microbatch4": {"bf16_attn": True, "microbatch_div": 4},
    "bf16_attn+headshard_off": {"bf16_attn": True, "headshard_off": True},
    "bf16_attn+zero1_moe": {"bf16_attn": True, "zero1_moe": True},
    "moe_grouped+headshard_off": {"dispatch_groups": 16, "headshard_off": True},
}


def apply_flags(flags):
    from repro.models import attention as A
    A.BF16_EINSUMS = bool(flags.get("bf16_attn"))
    if flags.get("zero1_moe"):
        import repro.sharding.rules as R
        R.ZERO1_MOE = True
    if flags.get("dispatch_groups"):
        from repro.models import moe as MO
        MO.DISPATCH_GROUPS = int(flags["dispatch_groups"])
    if flags.get("headshard_off"):
        import repro.sharding.rules as R
        R.shard_heads_impl = R.shard_heads
        # monkeypatch to no-op; restored per-process (one variant per process)
        import repro.sharding as S

        def noop(x, head_axis=2, dim_axis=3):
            return x
        R.shard_heads = noop
        S.shard_heads = noop
        from repro.models import attention as A2  # noqa: F401 — rebind late import site
        # attention imports shard_heads lazily inside _project_qkv, so the
        # rules-module patch is sufficient.


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--out", default="benchmarks/results/hillclimb.json")
    ap.add_argument("--with-memory", action="store_true",
                    help="also compile the rolled production program for "
                         "memory_analysis (slower)")
    args = ap.parse_args()

    flags = VARIANTS[args.variant]
    apply_flags(flags)

    from repro.configs import SHAPES_BY_NAME, get_config
    from repro.launch.dryrun import (active_param_count, lower_and_compile,
                                     probe_roofline, _cost_vector)
    from repro.launch.mesh import make_production_mesh
    from repro.analysis.roofline import HW, model_flops_estimate

    cfg = get_config(args.arch)
    shape = SHAPES_BY_NAME[args.shape]
    mesh = make_production_mesh(multi_pod=False)

    chunks = {}
    if flags.get("microbatch_div"):
        chunks["microbatch"] = max(1, shape.global_batch // flags["microbatch_div"])

    rec = {"arch": args.arch, "shape": args.shape, "variant": args.variant}
    full = probe_roofline(cfg, shape, mesh) if not flags.get("microbatch_div") \
        else probe_roofline_with_chunks(cfg, shape, mesh, chunks)
    rec["cost"] = full
    rec["terms"] = {
        "compute_s": full["flops"] / HW["peak_flops"],
        "memory_s": full["bytes"] / HW["hbm_bw"],
        "collective_s": full["coll"] / HW["ici_bw"],
    }
    dom = max(rec["terms"], key=rec["terms"].get)
    rec["bottleneck"] = dom
    n_act = active_param_count(cfg)
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill") else shape.global_batch)
    mf = model_flops_estimate(n_act, tokens,
                              "train" if shape.kind == "train" else "infer")
    rec["useful"] = mf / max(full["flops"] * 256, 1.0)

    if args.with_memory:
        _, compiled, dt = lower_and_compile(cfg, shape, mesh, chunks=chunks)
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            "argument_bytes_per_dev": ma.argument_size_in_bytes,
            "temp_bytes_per_dev": ma.temp_size_in_bytes,
        }

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    hist = []
    if os.path.exists(args.out):
        hist = json.load(open(args.out))
    hist.append(rec)
    json.dump(hist, open(args.out, "w"), indent=1)
    print(json.dumps({k: rec[k] for k in ("arch", "shape", "variant",
                                          "terms", "bottleneck", "useful")},
                     indent=1))


def probe_roofline_with_chunks(cfg, shape, mesh, chunks):
    """probe_roofline but honoring extra chunk knobs (microbatch)."""
    from repro.launch.dryrun import _probe_plan, lower_and_compile, _cost_vector
    T = shape.seq_len
    base = {"q_chunk": min(4096, T), "kv_chunk": min(4096, T),
            "loss_chunk": min(4096, T), "ssd_chunk": 128}
    base.update(chunks)
    kind, probes, full = _probe_plan(cfg)
    vecs = []
    for pc in probes:
        _, compiled, dt = lower_and_compile(pc, shape, mesh, chunks=base,
                                            unroll=True)
        vecs.append(_cost_vector(compiled))
    keys = sorted(set().union(*[set(v) for v in vecs]))
    out = {}
    if kind == "linear":
        (ca, ua), (cb, ub) = (vecs[0], 1), (vecs[1], 2)
        for k in keys:
            per = (cb.get(k, 0.0) - ca.get(k, 0.0)) / (ub - ua)
            out[k] = ca.get(k, 0.0) + (full - ua) * per
    else:
        cA, cB, cC = vecs
        n_shared, n_mamba = full
        for k in keys:
            m = (cB.get(k, 0.0) - cA.get(k, 0.0)) / 3.0
            s = cC.get(k, 0.0) - cB.get(k, 0.0)
            f = cA.get(k, 0.0) - s - 3 * m
            out[k] = f + n_shared * s + n_mamba * m
    return out


if __name__ == "__main__":
    main()
